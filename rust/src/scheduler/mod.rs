//! Continuous-batching scheduler: step-level batched serving with
//! KV-aware admission and priority preemption.
//!
//! Replaces the single-worker FIFO router's execution model.  One
//! composer thread owns the engine and drives three mechanisms:
//!
//! 1. **Admission** — a bounded multi-class queue ([`queue`]); beyond
//!    `max_queue` outstanding requests new arrivals are rejected with the
//!    `overloaded` error.  A queued request is admitted into the running
//!    set only when (a) a batch slot is free (`max_batch`) and (b) both
//!    model KV partitions can hold its worst-case token need on top of
//!    every in-flight sequence's reservation (the block-granular ledger
//!    in [`kv_fits`], backed by the `KvManager` free-block queries) — so
//!    an admitted request can never hit a KV-exhaustion error mid-flight.
//! 2. **Step-level batch composition** ([`task::tick`]) — every in-flight
//!    sequence exposes its next [`EngineOp`](crate::coordinator::EngineOp)
//!    via its re-entrant [`StepMachine`]; front ops are grouped by
//!    [`TaskPhase`](crate::coordinator::TaskPhase) (speculate / verify /
//!    fallback / answer) into one batched engine pass (`decode_batch` /
//!    `scored_prefill_batch`) per phase per step.  Those passes fan out
//!    over the process-wide work-stealing executor's pinned workers
//!    (scoped, no per-batch thread spawns — see [`crate::exec`]); the
//!    composer helps run its own batch jobs, so a saturated pool can
//!    slow a step but never deadlock it.
//! 3. **Preemption** — when the queue head belongs to a strictly higher
//!    class than some running sequence and no slot/KV is available, the
//!    lowest-priority (least-progressed on ties) running sequence is
//!    evicted: its KV is rolled back to the prompt and released, and its
//!    job re-queued at the front of its class for a from-scratch restart.
//!    Restarts are free of result skew — the op stream is a pure function
//!    of the request, so a preempted request's final `QueryMetrics` are
//!    identical to an undisturbed run (only wall/queue times differ).
//!
//! Determinism contract: at `max_batch = 1` the scheduler executes
//! exactly the serial path (`run_query` + `RealBackend`) — same ops, same
//! decode seeds, same metric fold order — so per-request deterministic
//! `QueryMetrics` (GPU clock, token/step counters, verify scores,
//! correctness) are bit-identical to the pre-scheduler router.  At any
//! `max_batch`, per-request results are independent of batchmates; only
//! throughput and wall-clock change.

pub mod queue;
mod task;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::DeployConfig;
use crate::coordinator::{Combo, Scheme, SeedStream, SpecConfig, StepMachine};
use crate::engine::Engine;
use crate::metrics::QueryMetrics;
use crate::semantics::{Dataset, DatasetProfile, Oracle, TraceGenerator};
use crate::util::json::Json;

pub use queue::{AdmissionQueue, Priority};
use task::SeqTask;

/// A fully-resolved serving request (the router applies per-request
/// overrides onto the deployment defaults before submitting).
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub dataset: Dataset,
    pub query_index: usize,
    pub sample: usize,
    pub seed: u64,
    pub spec: SpecConfig,
    pub priority: Priority,
}

/// What a completed request reports back.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub metrics: QueryMetrics,
    pub scheme: Scheme,
    pub priority: Priority,
    /// Submit → admission into the running set.
    pub queue_wait_s: f64,
    /// Submit → first engine op (time-to-first-step).
    pub ttfs_s: f64,
    /// Submit → completion.
    pub e2e_s: f64,
    /// Times this request was preempted and restarted.
    pub preemptions: u32,
}

/// Internal queue entry.
pub(crate) struct Job {
    pub req: JobRequest,
    pub reply: mpsc::Sender<Result<JobResult>>,
    pub submitted_at: Instant,
    /// First engine op *ever* for this request — survives preemption
    /// restarts so TTFS keeps its submit→first-op meaning.
    pub first_op_at: Option<Instant>,
    pub preemptions: u32,
}

/// Serving statistics (served over the `stats` op).  Extends the old
/// router counters with queue-wait / time-to-first-step / SLO / batching
/// telemetry.
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    pub admitted: u64,
    pub rejected_overload: u64,
    pub completed: u64,
    pub failed: u64,
    pub preempted: u64,
    pub queue_depth: usize,
    pub running: usize,
    /// Queue-wait accounting over engine admissions (re-admissions after
    /// preemption count again).
    pub queue_wait_samples: u64,
    pub queue_wait_s_sum: f64,
    pub queue_wait_s_max: f64,
    /// Submit → first engine op, summed over completed requests.
    pub ttfs_s_sum: f64,
    /// Completed requests whose end-to-end latency exceeded
    /// `DeployConfig::slo_ms` (0 disables).
    pub slo_violations: u64,
    /// Composed batch steps and the sequences they advanced.
    pub batch_ticks: u64,
    pub stepped_seqs: u64,
}

impl RouterStats {
    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.queue_wait_samples == 0 {
            0.0
        } else {
            self.queue_wait_s_sum / self.queue_wait_samples as f64
        }
    }

    pub fn mean_ttfs_s(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.ttfs_s_sum / self.completed as f64
        }
    }

    /// Mean sequences advanced per composed batch step.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_ticks == 0 {
            0.0
        } else {
            self.stepped_seqs as f64 / self.batch_ticks as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("admitted", Json::num(self.admitted as f64)),
            ("rejected_overload", Json::num(self.rejected_overload as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("preempted", Json::num(self.preempted as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("running", Json::num(self.running as f64)),
            ("queue_wait_s_mean", Json::num(self.mean_queue_wait_s())),
            ("queue_wait_s_max", Json::num(self.queue_wait_s_max)),
            ("ttfs_s_mean", Json::num(self.mean_ttfs_s())),
            ("slo_violations", Json::num(self.slo_violations as f64)),
            ("batch_ticks", Json::num(self.batch_ticks as f64)),
            ("batch_occupancy_mean", Json::num(self.mean_batch_occupancy())),
        ])
    }
}

struct Shared {
    queue: Mutex<AdmissionQueue<Job>>,
    cv: Condvar,
    stats: Mutex<RouterStats>,
    closed: AtomicBool,
}

/// Lock that survives poisoning: if the composer thread panicked while
/// holding a lock, the state it protects is still the best available
/// answer (counters, queue entries) and the liveness guard must be able
/// to drain the queue regardless.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Liveness guard: runs when the composer thread exits for *any* reason
/// — clean shutdown, startup failure, or a panic mid-serve.  Marks the
/// scheduler closed (so submits stop accepting) and fails every job
/// still queued, so no client can block forever on a reply that will
/// never come (the old router surfaced this as "engine worker is gone").
struct WorkerGuard {
    shared: Arc<Shared>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        let mut q = lock(&self.shared.queue);
        let mut stranded = 0u64;
        while let Some((_prio, job)) = q.pop() {
            stranded += 1;
            let _ = job.reply.send(Err(anyhow!("scheduler worker terminated")));
        }
        let mut s = lock(&self.shared.stats);
        s.failed += stranded;
        s.queue_depth = 0;
        s.running = 0;
    }
}

pub struct Scheduler {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    /// Spawn the composer thread.  The engine is created *inside* the
    /// worker (it owns the PJRT client for its lifetime); startup errors
    /// propagate here.
    pub fn start(cfg: DeployConfig) -> Result<Scheduler> {
        let shared = Arc::new(Shared {
            queue: Mutex::new(AdmissionQueue::new(cfg.max_queue)),
            cv: Condvar::new(),
            stats: Mutex::new(RouterStats::default()),
            closed: AtomicBool::new(false),
        });
        let wshared = Arc::clone(&shared);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("specreason-sched".into())
            .spawn(move || worker_loop(cfg, wshared, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("scheduler worker died during startup"))??;
        Ok(Scheduler { shared, worker: Some(worker) })
    }

    /// Try to admit a request into the wait queue; `Err` means
    /// backpressure (`overloaded`) or shutdown.  The returned channel
    /// yields the request's result when it completes.
    pub fn submit(&self, req: JobRequest) -> Result<mpsc::Receiver<Result<JobResult>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let prio = req.priority;
        let job = Job {
            req,
            reply: reply_tx,
            submitted_at: Instant::now(),
            first_op_at: None,
            preemptions: 0,
        };
        {
            let mut q = lock(&self.shared.queue);
            // Checked *under the queue lock*: the worker's liveness guard
            // sets `closed` and then drains the queue under this same
            // lock, so a submit can never slip a job in after the final
            // drain (it either lands before — and gets drained — or sees
            // `closed` here).
            anyhow::ensure!(
                !self.shared.closed.load(Ordering::SeqCst),
                "scheduler is shut down"
            );
            match q.push(prio, job) {
                Ok(()) => {
                    let mut s = lock(&self.shared.stats);
                    s.admitted += 1;
                    s.queue_depth = q.len();
                }
                Err(_rejected) => {
                    lock(&self.shared.stats).rejected_overload += 1;
                    anyhow::bail!("overloaded: admission queue full");
                }
            }
        }
        self.shared.cv.notify_all();
        Ok(reply_rx)
    }

    pub fn stats(&self) -> RouterStats {
        lock(&self.shared.stats).clone()
    }

    /// Stop the worker: in-flight and already-queued requests finish,
    /// then the thread joins.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Worst-case KV tokens a request can reach in either model's partition:
/// profile-maximal prompt + thinking budget + transient verification
/// template + answer, plus draft-overshoot slack for spec-decode rounds.
fn need_tokens(req: &JobRequest) -> usize {
    let prompt_hi = DatasetProfile::of(req.dataset).prompt_len.1;
    prompt_hi
        + req.spec.token_budget
        + req.spec.verify_template_len
        + req.spec.answer_tokens
        + req.spec.draft_k
        + 1
}

/// KV reservation ledger: would admitting a request of `need_new` tokens
/// stay within `model`'s partition even if every in-flight sequence grew
/// to its own worst case?  Block-granular (each sequence rounds up to
/// whole blocks), so an admitted request can never hit a KV-exhaustion
/// error mid-flight.  Subsumes the instantaneous free-block check
/// ([`Engine::kv_can_reserve`]) because this scheduler's sequences are
/// the partitions' only consumers.
fn kv_fits(engine: &Engine, model: &str, running: &[SeqTask<'_>], need_new: usize) -> bool {
    let Ok(pool) = engine.kv_pool_config(model) else {
        return false;
    };
    let bs = pool.block_size.max(1);
    let reserved: usize = running.iter().map(|t| t.need_tokens.div_ceil(bs)).sum();
    // Ledger bound, plus the live free-block query as defense in depth
    // (protects embedders that run other sequences on the same engine).
    reserved + need_new.div_ceil(bs) <= pool.total_blocks
        && engine.kv_can_reserve(model, need_new)
}

/// Could a request of `need` tokens ever fit `model`'s partition, even
/// with the engine idle?
fn kv_feasible(engine: &Engine, model: &str, need: usize) -> bool {
    match engine.kv_pool_config(model) {
        Ok(pool) => need.div_ceil(pool.block_size.max(1)) <= pool.total_blocks,
        Err(_) => false,
    }
}

/// Reject budgets that cannot fit the context window before any compute.
/// The prompt bound is derived from the dataset profile (the generator's
/// actual range), so the two cannot drift.
fn validate_budget(
    engine: &Engine,
    base_model: &str,
    dataset: Dataset,
    spec: &SpecConfig,
) -> Result<()> {
    let base = engine.model(base_model)?;
    let max_prompt = DatasetProfile::of(dataset).prompt_len.1;
    let need = max_prompt + spec.token_budget + spec.verify_template_len + spec.answer_tokens;
    anyhow::ensure!(
        need <= base.arch.max_seq,
        "token_budget {} does not fit the context window ({} needed > {})",
        spec.token_budget,
        need,
        base.arch.max_seq
    );
    Ok(())
}

fn worker_loop(cfg: DeployConfig, shared: Arc<Shared>, ready_tx: mpsc::Sender<Result<()>>) {
    // From here on, however this thread exits — clean shutdown, startup
    // failure, or a panic — the guard closes the scheduler and fails
    // whatever is still queued, so clients never hang on a dead worker.
    let _guard = WorkerGuard { shared: Arc::clone(&shared) };
    let engine = match Engine::new(&cfg.engine_config()) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let oracle = Oracle::default();
    let combo = Combo::new(&cfg.base_model, &cfg.small_model);
    let mut running: Vec<SeqTask> = Vec::new();

    loop {
        admit(&engine, &oracle, &combo, &cfg, &shared, &mut running);
        lock(&shared.stats).running = running.len();

        if running.is_empty() {
            let q = lock(&shared.queue);
            if q.is_empty() {
                if shared.closed.load(Ordering::SeqCst) {
                    break;
                }
                // Idle: wait for a submit (or shutdown) notification.
                let _unused = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
                continue;
            }
            // Queue non-empty but nothing admitted: admit() guarantees
            // progress when the running set is empty (it fails requests
            // that can never fit), so just loop.
            continue;
        }

        let report = task::tick(&engine, &combo, &mut running);
        if report.stepped > 0 {
            let mut s = lock(&shared.stats);
            s.batch_ticks += 1;
            s.stepped_seqs += report.stepped as u64;
        }
        finalize(&engine, &cfg, &shared, &mut running);
    }

    // Shutdown with the queue drained; nothing should be left in flight,
    // but release anything that is.
    for t in running.drain(..) {
        let _ = engine.release(&t.seq);
        let _ = t.job.reply.send(Err(anyhow!("scheduler shut down")));
    }
}

fn pop_job(shared: &Shared) -> Option<(Priority, Job)> {
    let mut q = lock(&shared.queue);
    let popped = q.pop();
    if popped.is_some() {
        lock(&shared.stats).queue_depth = q.len();
    }
    popped
}

/// Re-queue a job at the front of its class (it was popped but cannot
/// run yet — blocked or preemption-pending).
fn requeue_front(shared: &Shared, prio: Priority, job: Job) {
    let mut q = lock(&shared.queue);
    q.push_front(prio, job);
    lock(&shared.stats).queue_depth = q.len();
}

/// Admit queued jobs while batch slots and KV capacity allow, preempting
/// lower-class running sequences when a higher class would otherwise
/// starve.  Every decision is made about the job actually *popped* (not a
/// peeked snapshot), so a concurrent submit can never swap the job under
/// an admission decision; a blocked job goes back to the front of its
/// class untouched.
fn admit<'e>(
    engine: &'e Engine,
    oracle: &'e Oracle,
    combo: &'e Combo,
    cfg: &DeployConfig,
    shared: &Shared,
    running: &mut Vec<SeqTask<'e>>,
) {
    let max_batch = cfg.max_batch.max(1);
    loop {
        let Some((prio, job)) = pop_job(shared) else { return };
        let need = need_tokens(&job.req);

        // Never-serviceable requests fail fast — *before* the
        // fits/preemption decision, so an invalid (or oversized) request
        // can never evict another tenant's in-flight work on its way to
        // a rejection.
        if let Err(e) = validate_budget(engine, &combo.base, job.req.dataset, &job.req.spec) {
            lock(&shared.stats).failed += 1;
            let _ = job.reply.send(Err(e));
            continue;
        }
        if !kv_feasible(engine, &combo.small, need) || !kv_feasible(engine, &combo.base, need) {
            lock(&shared.stats).failed += 1;
            let _ = job.reply.send(Err(anyhow!(
                "request needs {need} KV tokens; exceeds partition capacity"
            )));
            continue;
        }

        let full = running.len() >= max_batch;
        let fits = !full
            && kv_fits(engine, &combo.small, running, need)
            && kv_fits(engine, &combo.base, running, need);

        if !fits {
            // This job outranks a running sequence: evict the weakest and
            // retry (the job returns to its class front, so it is the
            // next candidate unless an even higher class arrives).
            if cfg.preempt {
                if let Some(victim) = victim_index(running, prio) {
                    requeue_front(shared, prio, job);
                    preempt(engine, shared, running, victim);
                    continue;
                }
            }
            if running.is_empty() {
                // Feasible on an idle engine but blocked with nothing
                // running should be impossible (the ledger is empty);
                // fail defensively rather than risk a busy spin.
                lock(&shared.stats).failed += 1;
                let _ = job.reply.send(Err(anyhow!(
                    "request needs {need} KV tokens but cannot be scheduled"
                )));
                continue;
            }
            // Blocked behind the current batch: wait at the class front.
            requeue_front(shared, prio, job);
            return;
        }

        let wait = job.submitted_at.elapsed().as_secs_f64();
        {
            let mut s = lock(&shared.stats);
            s.queue_wait_samples += 1;
            s.queue_wait_s_sum += wait;
            if wait > s.queue_wait_s_max {
                s.queue_wait_s_max = wait;
            }
        }
        match make_task(engine, oracle, combo, prio, job) {
            Ok(t) => running.push(t),
            Err((job, e)) => {
                lock(&shared.stats).failed += 1;
                let _ = job.reply.send(Err(e));
            }
        }
    }
}

/// Build the in-flight state for an admitted job (budget validation
/// already happened in [`admit`], before the preemption decision).
fn make_task<'e>(
    engine: &'e Engine,
    oracle: &'e Oracle,
    combo: &'e Combo,
    prio: Priority,
    job: Job,
) -> Result<SeqTask<'e>, (Job, anyhow::Error)> {
    let need_tokens = need_tokens(&job.req);
    // Deliberately NOT the eval query cache (`eval::qcache`): request
    // seeds are untrusted client input, so caching per (dataset, seed)
    // here would grow without bound.  Generation is cheap relative to a
    // query's engine work (and to a preemption restart's lost compute).
    let q = TraceGenerator::new(job.req.dataset, job.req.seed).query(job.req.query_index);
    let seq = match engine.new_sequence(&q.prompt) {
        Ok(s) => s,
        Err(e) => return Err((job, e)),
    };
    let seeds = SeedStream::new(q.seed);
    let machine = StepMachine::new(
        oracle,
        std::borrow::Cow::Owned(q),
        std::borrow::Cow::Borrowed(combo),
        std::borrow::Cow::Owned(job.req.spec.clone()),
        job.req.sample,
    );
    Ok(SeqTask {
        job,
        prio,
        machine,
        seq,
        seeds,
        qm: QueryMetrics::default(),
        need_tokens,
        admitted_at: Instant::now(),
        failed: None,
    })
}

/// The preemption victim for a waiting request of class `head`: the
/// lowest-priority running sequence with `prio < head`, breaking ties
/// toward the most recently admitted (least progress to discard).
fn victim_index(running: &[SeqTask<'_>], head: Priority) -> Option<usize> {
    select_victim(running.iter().map(|t| (t.prio, t.admitted_at)), head)
}

/// Victim-selection comparator over `(priority, admitted_at)` pairs —
/// separated from [`SeqTask`] so it is unit-testable without an engine.
fn select_victim(
    candidates: impl Iterator<Item = (Priority, Instant)>,
    head: Priority,
) -> Option<usize> {
    let mut best: Option<(usize, Priority, Instant)> = None;
    for (i, (prio, admitted_at)) in candidates.enumerate() {
        if prio >= head {
            continue;
        }
        best = match best {
            None => Some((i, prio, admitted_at)),
            Some((j, best_prio, best_at)) => {
                if prio < best_prio || (prio == best_prio && admitted_at > best_at) {
                    Some((i, prio, admitted_at))
                } else {
                    Some((j, best_prio, best_at))
                }
            }
        };
    }
    best.map(|(i, _, _)| i)
}

/// Evict a running sequence: discard its speculative KV (rollback to the
/// prompt), release its blocks, and re-queue its job at the front of its
/// class for a from-scratch restart.
fn preempt<'e>(
    engine: &Engine,
    shared: &Shared,
    running: &mut Vec<SeqTask<'e>>,
    idx: usize,
) {
    let mut t = running.remove(idx);
    let prompt_len = t.seq.prompt_len;
    let _ = engine.rollback(&mut t.seq, prompt_len);
    let _ = engine.release(&t.seq);
    let mut job = t.job;
    job.preemptions += 1;
    let mut q = lock(&shared.queue);
    q.push_front(t.prio, job);
    let mut s = lock(&shared.stats);
    s.preempted += 1;
    s.queue_depth = q.len();
}

/// Retire finished (or failed) sequences: release KV, reply, count.
fn finalize(engine: &Engine, cfg: &DeployConfig, shared: &Shared, running: &mut Vec<SeqTask<'_>>) {
    let mut i = 0;
    while i < running.len() {
        let done = running[i].failed.is_some() || running[i].machine.is_done();
        if !done {
            i += 1;
            continue;
        }
        let t = running.remove(i);
        let _ = engine.release(&t.seq);
        let SeqTask { job, prio, qm, admitted_at, failed, .. } = t;
        let e2e_s = job.submitted_at.elapsed().as_secs_f64();
        match failed {
            Some(e) => {
                lock(&shared.stats).failed += 1;
                let _ = job.reply.send(Err(e));
            }
            None => {
                let queue_wait_s = admitted_at.duration_since(job.submitted_at).as_secs_f64();
                let ttfs_s = job
                    .first_op_at
                    .map(|at| at.duration_since(job.submitted_at).as_secs_f64())
                    .unwrap_or(e2e_s);
                {
                    let mut s = lock(&shared.stats);
                    s.completed += 1;
                    s.ttfs_s_sum += ttfs_s;
                    if cfg.slo_ms > 0 && e2e_s * 1000.0 > cfg.slo_ms as f64 {
                        s.slo_violations += 1;
                    }
                }
                let result = JobResult {
                    metrics: qm,
                    scheme: job.req.spec.scheme,
                    priority: prio,
                    queue_wait_s,
                    ttfs_s,
                    e2e_s,
                    preemptions: job.preemptions,
                };
                let _ = job.reply.send(Ok(result));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_json_shape() {
        let mut s = RouterStats::default();
        s.admitted = 5;
        s.rejected_overload = 1;
        s.completed = 3;
        s.queue_wait_samples = 3;
        s.queue_wait_s_sum = 0.6;
        s.ttfs_s_sum = 0.9;
        s.batch_ticks = 4;
        s.stepped_seqs = 10;
        let j = s.to_json();
        assert_eq!(j.get("admitted").as_usize(), Some(5));
        assert_eq!(j.get("rejected_overload").as_usize(), Some(1));
        assert_eq!(j.get("completed").as_usize(), Some(3));
        assert!((j.get("queue_wait_s_mean").as_f64().unwrap() - 0.2).abs() < 1e-12);
        assert!((j.get("ttfs_s_mean").as_f64().unwrap() - 0.3).abs() < 1e-12);
        assert!((j.get("batch_occupancy_mean").as_f64().unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn need_tokens_uses_profile_prompt_bound() {
        let spec = SpecConfig::default();
        let req = JobRequest {
            dataset: Dataset::Gpqa,
            query_index: 0,
            sample: 0,
            seed: 1,
            spec: spec.clone(),
            priority: Priority::Normal,
        };
        let expect = DatasetProfile::of(Dataset::Gpqa).prompt_len.1
            + spec.token_budget
            + spec.verify_template_len
            + spec.answer_tokens
            + spec.draft_k
            + 1;
        assert_eq!(need_tokens(&req), expect);
    }

    // Victim selection against the production comparator: lowest class
    // first, then least progress (most recently admitted).
    #[test]
    fn victim_prefers_lowest_class_then_newest() {
        let now = Instant::now();
        let candidates = [
            (Priority::Low, now),
            (Priority::Normal, now + Duration::from_millis(1)),
            (Priority::Low, now + Duration::from_millis(2)),
        ];
        // The newest Low entry wins for a High head.
        assert_eq!(select_victim(candidates.iter().copied(), Priority::High), Some(2));
        // A Normal head may only evict Lows.
        assert_eq!(select_victim(candidates.iter().copied(), Priority::Normal), Some(2));
        // Nothing qualifies for a Low head (strictly-lower rule).
        assert_eq!(select_victim(candidates.iter().copied(), Priority::Low), None);
        // Same class never preempts itself.
        let same = [(Priority::High, now)];
        assert_eq!(select_victim(same.iter().copied(), Priority::High), None);
    }
}
