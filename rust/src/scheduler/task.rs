//! In-flight sequence state and the step-level batch composer.
//!
//! A [`SeqTask`] bundles everything one admitted request needs to be
//! driven re-entrantly: its [`StepMachine`] (the op stream), its engine
//! [`Sequence`], decode-seed stream and metrics.  [`tick`] advances every
//! in-flight task by (at most) one engine op, grouping front ops by their
//! [`TaskPhase`] into one batched engine pass per phase:
//!
//! * speculate / fallback / answer decode groups →
//!   [`Engine::decode_batch`] (one pass per phase group); spec-decode
//!   bonus tokens are real decodes and ride the fallback group, with
//!   their zero-GPU-cost accounting applied after the pass;
//! * verification ops (templated §4.1 scoring and spec-decode catch-up) →
//!   [`Engine::scored_prefill_batch`];
//! * rollbacks (pure KV bookkeeping, no compute) execute inline before
//!   the batches are composed;
//! * lookahead draft-ahead ops run in follow-on sub-rounds *within the
//!   same tick*, so a sequence whose verification just committed
//!   contributes both that verify and its optimistic draft suffix to
//!   one scheduling step (inert at `lookahead_k = 0`).
//!
//! Per-task op order is exactly the machine's plan order, and each task's
//! ops run on its own sequence, so a task's results are independent of
//! its batchmates — at `max_batch = 1` the composed "batch" degenerates
//! to the serial path.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::coordinator::{
    arm_overlap_window, credit_draft_overlap, execute_op, inject_op_fault, lookahead_gpu,
    verify_template, Combo, EngineOp, Role, SeedStream, StepMachine, TaskPhase,
};
use crate::engine::{BatchDecode, BatchVerify, Engine, Sequence};
use crate::metrics::{Phase, QueryMetrics};
use crate::obs::{Obs, Tracer};

use super::queue::Priority;
use super::Job;

/// Per-task span derivation state: a snapshot of the task's
/// `QueryMetrics` phase accumulators at the last committed op.  After
/// each commit, every accumulator that moved emits one trace span with
/// the wall/GPU deltas — so span sums reconstruct the request's phase
/// breakdown from exactly the numbers the result reports, and the
/// engine/coordinator stay untouched.
pub(crate) struct TraceCursor {
    id: u64,
    wall: BTreeMap<&'static str, f64>,
    gpu: BTreeMap<&'static str, f64>,
}

impl TraceCursor {
    pub fn new(id: u64) -> TraceCursor {
        TraceCursor { id, wall: BTreeMap::new(), gpu: BTreeMap::new() }
    }

    /// Emit spans for phase accumulators that changed since the last
    /// sync (a GPU-only change — e.g. the bonus-token refund — still
    /// counts), and advance the snapshot.
    fn sync(&mut self, tracer: &Tracer, qm: &QueryMetrics) {
        for (&phase, &wall) in qm.phase_wall.iter() {
            let gpu = qm.phase_gpu.get(phase).copied().unwrap_or(0.0);
            let prev_w = self.wall.get(phase).copied().unwrap_or(0.0);
            let prev_g = self.gpu.get(phase).copied().unwrap_or(0.0);
            if wall != prev_w || gpu != prev_g {
                tracer.span(self.id, phase, wall - prev_w, gpu - prev_g);
                self.wall.insert(phase, wall);
                self.gpu.insert(phase, gpu);
            }
        }
    }
}

/// One admitted, in-flight sequence.
pub(crate) struct SeqTask<'e> {
    pub job: Job,
    pub prio: Priority,
    pub machine: StepMachine<'e>,
    pub seq: Sequence,
    pub seeds: SeedStream,
    pub qm: QueryMetrics,
    /// Worst-case KV tokens this sequence can still demand, per model
    /// partition, *net of its adopted shared prefix* (the admission
    /// ledger).  With the prefix cache off this is the same worst case
    /// for every model — the old single `need_tokens`.
    pub reserve: BTreeMap<String, usize>,
    pub admitted_at: Instant,
    pub failed: Option<anyhow::Error>,
    /// Front ops executed (or attempted) this admission — the op index
    /// fed to the `engine_op` fault site.  Resets with the task on every
    /// restart, so together with [`Job::attempt`] each replay walks a
    /// fresh deterministic fault schedule.
    pub ops_executed: u64,
    /// Span-derivation snapshot (`None` with tracing off — the only
    /// cost then is this one branch per commit).
    pub traced: Option<TraceCursor>,
}

impl SeqTask<'_> {
    /// This task's ledger reservation in `model`'s partition, in blocks.
    pub fn reserve_blocks(&self, model: &str, block_size: usize) -> usize {
        self.reserve
            .get(model)
            .copied()
            .unwrap_or(0)
            .div_ceil(block_size.max(1))
    }

    /// `engine_op`-site fault gate for this task's next front op: fires
    /// *before* the op executes or joins a batch, so a faulted step
    /// leaves the sequence at its pre-op state for the rollback/retry
    /// path.  Returns `false` (and marks the task failed) when a fault
    /// fired; inert without an armed plan.
    fn gate_front_op(&mut self, engine: &Engine) -> bool {
        let op_index = self.ops_executed;
        self.ops_executed += 1;
        match inject_op_fault(engine.faults(), self.job.req.seed, self.job.attempt(), op_index) {
            Ok(()) => true,
            Err(e) => {
                self.failed = Some(e);
                false
            }
        }
    }

    /// Record the request's first engine op (on the `Job`, so the
    /// timestamp survives preemption restarts).
    pub fn note_first_op(&mut self) {
        if self.job.first_op_at.is_none() {
            // speclint: allow(d1-nondet) — TTFS metric timestamp only;
            // never read by StepMachine/policy decisions.
            self.job.first_op_at = Some(Instant::now());
        }
    }

    /// Forward the step events published by the machine's latest commit
    /// to the job's event stream (send errors mean the client dropped
    /// its handle; the reaper will collect the cancel flag).
    pub fn flush_events(&mut self) {
        for ev in self.machine.take_events() {
            if self.job.first_event_at.is_none() {
                // speclint: allow(d1-nondet) — TTFE metric timestamp
                // only; the event payload it stamps is already decided.
                self.job.first_event_at = Some(Instant::now());
            }
            let _ = self.job.events.send(super::JobEvent::Step(ev));
        }
    }
}

/// Outcome of one composed tick (for stats).
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct TickReport {
    /// Sequences that advanced through a batched engine pass.
    pub stepped: usize,
}

/// Advance every runnable task by one engine op, batched by op kind.
pub(crate) fn tick(
    engine: &Engine,
    combo: &Combo,
    running: &mut [SeqTask<'_>],
    obs: &Obs,
) -> TickReport {
    // --- rollbacks run inline (pure KV bookkeeping, no engine pass) ---
    for t in running.iter_mut() {
        if t.failed.is_some() {
            continue;
        }
        loop {
            let op = match t.machine.peek() {
                Some(op @ EngineOp::Rollback { .. }) => op,
                _ => break,
            };
            if !t.gate_front_op(engine) {
                break;
            }
            t.note_first_op();
            match execute_op(
                engine,
                &combo.small,
                &combo.base,
                &mut t.seq,
                &mut t.seeds,
                op,
                &mut t.qm,
            ) {
                Ok(()) => {
                    t.machine.commit(&mut t.qm);
                    if let Some(c) = t.traced.as_mut() {
                        c.sync(&obs.tracer, &t.qm);
                    }
                    t.flush_events();
                }
                Err(e) => {
                    t.failed = Some(e);
                    break;
                }
            }
        }
    }

    // --- compose this step's batches from the front ops, grouped by
    // the machine's scheduling phase (speculate / verify / fallback /
    // answer) ---
    const SPECULATE: usize = 0;
    const FALLBACK: usize = 1;
    const ANSWER: usize = 2;
    let mut decode_groups: [(Vec<BatchDecode<'_>>, Vec<usize>); 3] =
        [(Vec::new(), Vec::new()), (Vec::new(), Vec::new()), (Vec::new(), Vec::new())];
    let mut verify_reqs: Vec<BatchVerify<'_>> = Vec::new();
    let mut verify_idx: Vec<usize> = Vec::new();
    // Spec-decode bonus tokens in this tick's fallback batch: (task
    // index, gpu_secs before the pass) — their decode is real compute
    // but charged zero GPU-clock (logits come free with the verification
    // pass), so the charge is subtracted once the batch returns, exactly
    // like the serial executor does.
    let mut bonus_before: Vec<(usize, f64)> = Vec::new();
    // `gpu_secs` before each composed verify pass, parallel to
    // `verify_idx`: once the pass commits, its span arms the task's
    // verify-overlap window (the same sample-execute-arm sequence the
    // serial executor runs) so this tick's lookahead draft sub-rounds
    // below can refund work hidden under it.
    let mut verify_before: Vec<f64> = Vec::new();
    for (i, t) in running.iter_mut().enumerate() {
        if t.failed.is_some() {
            continue;
        }
        let tphase = t.machine.phase();
        let Some(op) = t.machine.peek() else { continue };
        if matches!(op, EngineOp::DraftAhead { .. }) {
            // Lookahead drafts run in the sub-rounds below (after their
            // verify has committed and armed the window); skipping here
            // keeps the fault-site op index gated exactly once per op.
            continue;
        }
        if !t.gate_front_op(engine) {
            continue;
        }
        let (role, n, phase) = match op {
            EngineOp::Decode { role, n, phase } => (role, n, phase),
            EngineOp::Finish { role, n } => (role, n, Phase::Answer),
            EngineOp::BonusToken => {
                bonus_before.push((i, t.qm.gpu_secs));
                (Role::Base, 1, Phase::SpecVerify)
            }
            EngineOp::VerifyPass { template_len, phase } => {
                let template = if template_len == 0 {
                    Vec::new()
                } else {
                    verify_template(engine, template_len)
                };
                t.note_first_op();
                verify_before.push(t.qm.gpu_secs);
                verify_reqs.push(BatchVerify {
                    seq: &mut t.seq,
                    model: &combo.base,
                    template,
                    phase,
                    qm: &mut t.qm,
                });
                verify_idx.push(i);
                continue;
            }
            // Rollbacks were drained above; a fresh one can only appear
            // after this tick's batch op commits.
            _ => continue,
        };
        t.note_first_op();
        let model = match role {
            Role::Small => combo.small.as_str(),
            Role::Base => combo.base.as_str(),
        };
        let seed = t.seeds.next();
        let group = match tphase {
            TaskPhase::Speculate => SPECULATE,
            TaskPhase::Answer => ANSWER,
            _ => FALLBACK,
        };
        decode_groups[group]
            .0
            .push(BatchDecode { seq: &mut t.seq, model, n, seed, phase, qm: &mut t.qm });
        decode_groups[group].1.push(i);
    }

    let [spec_group, fallback_group, answer_group] = decode_groups;
    let mut stepped = verify_idx.len()
        + spec_group.1.len()
        + fallback_group.1.len()
        + answer_group.1.len();

    // --- one engine pass per phase group (all batches run before any
    // commit so the per-task borrows stay disjoint) ---
    let verify_results = engine.scored_prefill_batch(verify_reqs);
    let spec_results = engine.decode_batch(spec_group.0);
    let fallback_results = engine.decode_batch(fallback_group.0);
    let answer_results = engine.decode_batch(answer_group.0);

    let mut commit = |idx: &[usize], results: Vec<Result<(), anyhow::Error>>| {
        for (k, r) in results.into_iter().enumerate() {
            let t = &mut running[idx[k]];
            match r {
                Ok(()) => {
                    // Bonus-token zero-cost accounting: the shared
                    // refund keeps serial/batched parity exact.
                    if let Some(&(_, gpu_before)) =
                        bonus_before.iter().find(|(j, _)| *j == idx[k])
                    {
                        crate::coordinator::exec::refund_bonus_gpu(&mut t.qm, gpu_before);
                    }
                    t.machine.commit(&mut t.qm);
                    if let Some(c) = t.traced.as_mut() {
                        c.sync(&obs.tracer, &t.qm);
                    }
                    t.flush_events();
                }
                Err(e) => t.failed = Some(e),
            }
        }
    };
    commit(&verify_idx, drop_payload(verify_results));
    commit(&spec_group.1, drop_payload(spec_results));
    commit(&fallback_group.1, drop_payload(fallback_results));
    commit(&answer_group.1, drop_payload(answer_results));

    // --- arm each committed verify's overlap window (serial parity:
    // `EngineOp::apply` does the same around `backend.verify_pass`) ---
    for (k, &i) in verify_idx.iter().enumerate() {
        let t = &mut running[i];
        if t.failed.is_none() {
            arm_overlap_window(&mut t.qm, verify_before[k]);
        }
    }

    // --- lookahead draft sub-rounds: a sequence whose verify committed
    // above immediately contributes its draft-ahead ops to follow-on
    // small-model decode batches *within the same tick*, so one
    // sequence's verify and drafts share a scheduling step.  Each
    // sub-round advances every drafting task by one DraftAhead op; the
    // loop runs at most `lookahead_k` times and composes nothing at all
    // when lookahead is off (bit-identical tick).  ---
    loop {
        let mut draft_reqs: Vec<BatchDecode<'_>> = Vec::new();
        let mut draft_idx: Vec<usize> = Vec::new();
        let mut draft_before: Vec<f64> = Vec::new();
        for (i, t) in running.iter_mut().enumerate() {
            if t.failed.is_some() {
                continue;
            }
            let Some(EngineOp::DraftAhead { n }) = t.machine.peek() else { continue };
            if !t.gate_front_op(engine) {
                continue;
            }
            t.note_first_op();
            let seed = t.seeds.next();
            draft_before.push(lookahead_gpu(&t.qm));
            draft_reqs.push(BatchDecode {
                seq: &mut t.seq,
                model: combo.small.as_str(),
                n,
                seed,
                phase: Phase::LookaheadDraft,
                qm: &mut t.qm,
            });
            draft_idx.push(i);
        }
        if draft_idx.is_empty() {
            break;
        }
        let draft_results = engine.decode_batch(draft_reqs);
        for (k, r) in drop_payload(draft_results).into_iter().enumerate() {
            let t = &mut running[draft_idx[k]];
            match r {
                Ok(()) => {
                    // Refund the part of the draft hidden under the
                    // armed verify window (same arithmetic as the
                    // serial executor, so metrics parity holds).
                    credit_draft_overlap(&mut t.qm, draft_before[k]);
                    t.machine.commit(&mut t.qm);
                    if let Some(c) = t.traced.as_mut() {
                        c.sync(&obs.tracer, &t.qm);
                    }
                    t.flush_events();
                    stepped += 1;
                }
                Err(e) => t.failed = Some(e),
            }
        }
    }

    TickReport { stepped }
}

/// Collapse per-request payloads to unit results (the composer only needs
/// success/failure; generated tokens already live in each sequence).
fn drop_payload<T>(results: Vec<Result<T, anyhow::Error>>) -> Vec<Result<(), anyhow::Error>> {
    results.into_iter().map(|r| r.map(|_| ())).collect()
}
