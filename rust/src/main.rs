//! `specreason` — CLI launcher for the SpecReason serving stack.
//!
//! Subcommands:
//!   serve   start the TCP serving front end
//!   run     run an evaluation cell and print a results table
//!   query   run a single query and print its metrics JSON
//!   info    summarize the artifact manifest
//!   help    this text

use anyhow::Result;

use specreason::config::DeployConfig;
use specreason::coordinator::{
    run_query, AcceptancePolicy, Combo, RealBackend, Scheme, SpecConfig,
};
use specreason::engine::Engine;
use specreason::eval::{Cell, Sweep};
use specreason::exec::{EnginePool, PinPolicy};
use specreason::semantics::{Dataset, Oracle, TraceGenerator};
use specreason::server::Server;
use specreason::util::bench::Table;
use specreason::util::cli::Command;

fn main() {
    if let Err(e) = dispatch() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "specreason — speculative reasoning serving stack

USAGE: specreason <serve|run|query|info|help> [options]

  serve   start the TCP JSON-line server (see --help)
  run     run an eval cell (dataset × scheme × combo), print a table
  query   run one query end-to-end, print metrics JSON
  info    summarize artifacts/manifest.json

Run `specreason <cmd> --help` for per-command options.";

fn dispatch() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match argv.first().map(|s| s.as_str()) {
        Some("serve") => cmd_serve(&argv[1..]),
        Some("run") => cmd_run(&argv[1..]),
        Some("query") => cmd_query(&argv[1..]),
        Some("info") => cmd_info(&argv[1..]),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn common_opts(cmd: Command) -> Command {
    cmd.opt("config", "deploy config JSON file", None)
        .opt("artifacts", "artifacts directory", Some("artifacts"))
        .opt("base", "base model name", Some("qwq-sim"))
        .opt("small", "speculator model name", Some("r1-sim"))
        .opt("scheme", "vanilla-base|vanilla-small|spec-decode|spec-reason|spec-reason+decode", Some("spec-reason"))
        .opt("threshold", "acceptance threshold 0-9", Some("7"))
        .opt("first-n-base", "force first n steps onto the base model", Some("0"))
        .opt("budget", "thinking-token budget", Some("704"))
}

fn deploy_from(args: &specreason::util::cli::Args) -> Result<DeployConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => DeployConfig::from_file(path)?,
        None => DeployConfig::default(),
    };
    cfg.artifacts_dir = args.get_or("artifacts", &cfg.artifacts_dir.clone()).to_string();
    cfg.base_model = args.get_or("base", &cfg.base_model.clone()).to_string();
    cfg.small_model = args.get_or("small", &cfg.small_model.clone()).to_string();
    cfg.scheme = Scheme::parse(args.get_or("scheme", cfg.scheme.name()))?;
    cfg.threshold = args.usize("threshold", cfg.threshold as usize)? as u8;
    cfg.first_n_base = args.usize("first-n-base", cfg.first_n_base)?;
    cfg.token_budget = args.usize("budget", cfg.token_budget)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Apply the shared executor options (`--threads`, backed by
/// `SPECREASON_BENCH_THREADS`, and `--pin`) onto a deploy config.
/// `--threads 0` is rejected with a clear error (omit it for auto).
fn exec_opts(cmd: Command) -> Command {
    cmd.opt_env(
        "threads",
        "executor worker threads shared by serving and sweeps (default: auto = available parallelism)",
        "SPECREASON_BENCH_THREADS",
        None,
    )
    .opt(
        "pin",
        "worker placement: floating|pinned (pinned records intent only for now — no affinity syscalls in the offline toolchain)",
        None,
    )
}

fn apply_exec_opts(cfg: &mut DeployConfig, args: &specreason::util::cli::Args) -> Result<()> {
    if let Some(v) = args.get("threads") {
        let n: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads expects a positive integer, got {v:?}"))?;
        anyhow::ensure!(
            n >= 1,
            "--threads/SPECREASON_BENCH_THREADS must be >= 1 (got 0); omit it for auto"
        );
        cfg.exec.workers = Some(n);
    }
    if let Some(v) = args.get("pin") {
        cfg.exec.pin = PinPolicy::parse(v)?;
    }
    Ok(())
}

fn cmd_serve(raw: &[String]) -> Result<()> {
    let cmd = exec_opts(common_opts(Command::new("specreason serve", "start the TCP server")))
        .opt("addr", "listen address", Some("127.0.0.1:7878"))
        .opt("max-batch", "in-flight sequences batched per engine step (1 = serial)", Some("1"))
        .opt(
            "replicas",
            "engine replicas behind prefix-affinity placement (1 = single-scheduler path)",
            Some("1"),
        )
        .opt(
            "lookahead",
            "draft up to k future steps while the base model verifies (0 = serial)",
            None,
        )
        .opt("seed", "default workload seed for requests that omit one", None)
        .flag(
            "prefix-cache",
            "share KV blocks across requests with a common prompt prefix",
        )
        .opt(
            "prefix-cache-blocks",
            "cached-block budget per KV partition (0 = bounded by the pool)",
            None,
        )
        .opt(
            "fault-plan",
            "deterministic fault injection, e.g. 'seed=7,rate=0.05,sites=engine_op+kv' ('none' = off)",
            None,
        )
        .flag(
            "trace",
            "per-request span tracing (served over the v2 'trace' op; off by default)",
        )
        .opt(
            "trace-dir",
            "export each finished trace as NDJSON into this directory (implies --trace)",
            None,
        );
    let args = cmd.parse(raw)?;
    let mut cfg = deploy_from(&args)?;
    cfg.addr = args.get_or("addr", &cfg.addr.clone()).to_string();
    cfg.max_batch = args.usize("max-batch", cfg.max_batch)?;
    cfg.replicas = args.usize("replicas", cfg.replicas)?;
    cfg.lookahead_k = args.usize("lookahead", cfg.lookahead_k)?;
    cfg.seed = args.u64("seed", cfg.seed)?;
    if args.flag("prefix-cache") {
        cfg.prefix_cache = true;
    }
    cfg.prefix_cache_blocks = args.usize("prefix-cache-blocks", cfg.prefix_cache_blocks)?;
    if let Some(plan) = args.get("fault-plan") {
        cfg.fault_plan = specreason::faults::FaultPlan::parse(plan)?;
    }
    if args.flag("trace") {
        cfg.obs_trace = true;
    }
    if let Some(dir) = args.get("trace-dir") {
        cfg.obs_trace = true;
        cfg.obs_trace_dir = dir.to_string();
    }
    apply_exec_opts(&mut cfg, &args)?;
    cfg.validate()?;
    eprintln!(
        "[serve] loading {} + {} from {} ({} replica{}) ...",
        cfg.base_model,
        cfg.small_model,
        cfg.artifacts_dir,
        cfg.replicas,
        if cfg.replicas == 1 { "" } else { "s" }
    );
    let server = Server::bind(cfg)?;
    eprintln!("[serve] listening on {}", server.addr);
    server.run()
}

fn cmd_run(raw: &[String]) -> Result<()> {
    let cmd = exec_opts(common_opts(Command::new("specreason run", "run an eval cell")))
        .opt("dataset", "aime|math500|gpqa", Some("aime"))
        .opt("queries", "number of queries", Some("8"))
        .opt("samples", "pass@1 samples per query", Some("2"))
        .opt("seed", "workload seed", Some("1234"))
        .flag("sim", "use the cost-model simulator instead of the engine");
    let args = cmd.parse(raw)?;
    let mut cfg = deploy_from(&args)?;
    apply_exec_opts(&mut cfg, &args)?;
    let dataset = Dataset::parse(args.get_or("dataset", "aime"))?;
    let queries = args.usize("queries", 8)?;
    let samples = args.usize("samples", 2)?;
    let seed = args.u64("seed", 1234)?;
    // One executor governs both paths: size the process-wide pool from
    // --threads / SPECREASON_BENCH_THREADS / auto, then run on it.
    let exec = specreason::exec::configure_global(&cfg.exec)?;
    let threads = exec.workers();

    let cell = Cell {
        dataset,
        scheme: cfg.scheme,
        combo: Combo::new(&cfg.base_model, &cfg.small_model),
        cfg: cfg.spec_config(),
    };
    let oracle = Oracle::default();
    let mut sweep = Sweep::new(queries, samples, seed);
    sweep.cell(cell);
    let result = if args.flag("sim") {
        eprintln!("[run] sweeping {} work items on {threads} threads (sim)", sweep.len());
        sweep.run_sim(&oracle)?.remove(0)
    } else {
        let n_engines = specreason::eval::engine_count(threads, sweep.len())?;
        eprintln!("[run] loading {n_engines} engine(s) ...");
        let pool = EnginePool::new(&cfg.engine_config(), n_engines)?;
        sweep.run_real_pool(&pool, &oracle)?.remove(0)
    };

    let mut t = Table::new(
        &format!("{} ({} queries × {} samples)", result.cell_label, queries, samples),
        &["metric", "value"],
    );
    t.row(vec!["pass@1".into(), format!("{:.3}", result.accuracy())]);
    t.row(vec!["mean latency (gpu clock, s)".into(), format!("{:.2}", result.mean_gpu())]);
    t.row(vec!["mean latency (wall, s)".into(), format!("{:.2}", result.mean_wall())]);
    t.row(vec!["mean thinking tokens".into(), format!("{:.0}", result.mean_tokens())]);
    t.row(vec!["offload ratio".into(), format!("{:.2}", result.mean_offload())]);
    t.row(vec!["acceptance rate".into(), format!("{:.2}", result.mean_acceptance())]);
    t.print();
    Ok(())
}

fn cmd_query(raw: &[String]) -> Result<()> {
    let cmd = common_opts(Command::new("specreason query", "run one query"))
        .opt("dataset", "aime|math500|gpqa", Some("aime"))
        .opt("index", "query index", Some("0"))
        .opt("sample", "pass@1 sample index", Some("0"))
        .opt("seed", "workload seed", Some("1234"));
    let args = cmd.parse(raw)?;
    let cfg = deploy_from(&args)?;
    let dataset = Dataset::parse(args.get_or("dataset", "aime"))?;
    let index = args.usize("index", 0)?;
    let sample = args.usize("sample", 0)?;
    let seed = args.u64("seed", 1234)?;

    eprintln!("[query] loading engine ...");
    let engine = Engine::new(&cfg.engine_config())?;
    let oracle = Oracle::default();
    let combo = Combo::new(&cfg.base_model, &cfg.small_model);
    let spec: SpecConfig = SpecConfig {
        policy: AcceptancePolicy::Static { threshold: cfg.threshold },
        ..cfg.spec_config()
    };
    let q = TraceGenerator::new(dataset, seed).query(index);
    let mut backend = RealBackend::new(&engine, &combo.small, &combo.base);
    let out = run_query(&oracle, &q, &combo, &spec, &mut backend, sample)?;
    backend.release()?;
    println!(
        "{}",
        specreason::server::protocol::metrics_to_json(&out.metrics, spec.scheme)
            .to_string_pretty()
    );
    Ok(())
}

fn cmd_info(raw: &[String]) -> Result<()> {
    let cmd = Command::new("specreason info", "summarize the artifact manifest")
        .opt("artifacts", "artifacts directory", Some("artifacts"));
    let args = cmd.parse(raw)?;
    let manifest = specreason::runtime::Manifest::load(args.get_or("artifacts", "artifacts"))?;
    let mut t = Table::new("artifact manifest", &["model", "arch", "params", "hlo files"]);
    for (name, entry) in &manifest.models {
        let arch = manifest.arch(&entry.arch)?;
        t.row(vec![
            name.clone(),
            entry.arch.clone(),
            format!("{:.1}M", arch.param_count as f64 / 1e6),
            format!("{} step + {} decode", arch.step_hlo.len(), arch.decode_hlo.len()),
        ]);
    }
    t.print();
    println!(
        "vocab={} block_k={} pallas={}",
        manifest.vocab, manifest.block_k, manifest.use_pallas
    );
    Ok(())
}
