//! Seeded, deterministic fault injection for the serving path.
//!
//! A [`FaultPlan`] names *where* failures may fire (injection sites),
//! *how often* (a per-decision probability), and *under which seed* —
//! every decision is a pure hash of `(seed, site, key)`, so a given
//! plan replays the exact same failure schedule on every run.  That
//! determinism is the point: the chaos suite can sweep seeds and assert
//! recovery invariants bit-for-bit, which a time- or entropy-based
//! injector can never support.
//!
//! Sites (see [`FaultSite`]):
//!
//! - `engine_op` — an in-flight [`StepMachine`](crate::coordinator::StepMachine)
//!   front op fails before execution (scheduler tick).
//! - `batch` — one slot of `Engine::decode_batch` /
//!   `Engine::scored_prefill_batch` fails (or panics, with
//!   `panic_in_batch`, to exercise the executor's panic isolation).
//! - `kv` — a KV reservation or block-growth attempt fails before any
//!   accounting mutates (engine `new_sequence` / growth paths).
//! - `conn_io` — a connection handler's read/write fails, dropping the
//!   connection (the server must survive; its jobs are cancelled).
//!
//! Injected failures carry an `injected:` message and classify as
//! `engine_failure` — the *transient* error class — so they exercise
//! the scheduler's retry/rollback path exactly like a real transient
//! fault would.  The default plan is [`FaultPlan::none`]: zero sites,
//! zero rate, and a disabled [`FaultInjector`] whose checks are a
//! single branch — serving behavior is bit-identical to a build
//! without this module.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::util::json::Json;

// ---------------------------------------------------------------------
// Sites
// ---------------------------------------------------------------------

/// A well-defined point in the serving path where a fault may fire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultSite {
    /// Engine-op execution inside the scheduler tick.
    EngineOp,
    /// One slot of a batched decode / scored-prefill pass.
    Batch,
    /// KV reservation or block growth (before accounting mutates).
    Kv,
    /// Connection I/O in a server handler.
    ConnIo,
}

impl FaultSite {
    pub const ALL: [FaultSite; 4] =
        [FaultSite::EngineOp, FaultSite::Batch, FaultSite::Kv, FaultSite::ConnIo];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::EngineOp => "engine_op",
            FaultSite::Batch => "batch",
            FaultSite::Kv => "kv",
            FaultSite::ConnIo => "conn_io",
        }
    }

    pub fn parse(s: &str) -> Result<FaultSite> {
        match s {
            "engine_op" => Ok(FaultSite::EngineOp),
            "batch" => Ok(FaultSite::Batch),
            "kv" => Ok(FaultSite::Kv),
            "conn_io" => Ok(FaultSite::ConnIo),
            other => bail!(
                "unknown fault site {other:?} (expected engine_op|batch|kv|conn_io|all)"
            ),
        }
    }

    fn index(self) -> usize {
        match self {
            FaultSite::EngineOp => 0,
            FaultSite::Batch => 1,
            FaultSite::Kv => 2,
            FaultSite::ConnIo => 3,
        }
    }
}

// ---------------------------------------------------------------------
// Plan
// ---------------------------------------------------------------------

/// Declarative fault schedule: seed + per-decision rate + enabled sites.
///
/// Carried by `DeployConfig` (JSON `"fault_plan"`) and `serve
/// --fault-plan`; the engine and server each build a [`FaultInjector`]
/// from it.  [`FaultPlan::none`] (the `Default`) injects nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for the pure decision hash; two runs with the same plan see
    /// the same failure schedule.
    pub seed: u64,
    /// Per-decision injection probability in `[0, 1]`.
    pub rate: f64,
    /// Sites where the plan is armed (empty ⇒ inert).
    pub sites: Vec<FaultSite>,
    /// Hard cap on the total faults an injector fires (0 ⇒ unlimited).
    pub max_faults: u64,
    /// `batch`-site faults panic inside the worker closure instead of
    /// returning an error — exercises the executor's panic isolation.
    pub panic_in_batch: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The inert plan: no sites, zero rate. Bit-identity escape hatch.
    pub fn none() -> FaultPlan {
        FaultPlan { seed: 0, rate: 0.0, sites: Vec::new(), max_faults: 0, panic_in_batch: false }
    }

    /// A plan armed at every site.
    pub fn all_sites(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { seed, rate, sites: FaultSite::ALL.to_vec(), ..FaultPlan::none() }
    }

    /// True when the plan can never fire.
    pub fn is_none(&self) -> bool {
        self.rate <= 0.0 || self.sites.is_empty()
    }

    pub fn site_enabled(&self, site: FaultSite) -> bool {
        self.sites.contains(&site)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.rate.is_finite() && (0.0..=1.0).contains(&self.rate),
            "fault_plan rate must be in [0, 1], got {}",
            self.rate
        );
        Ok(())
    }

    /// Parse the compact CLI form
    /// `seed=7,rate=0.05,sites=engine_op+batch+kv+conn_io[,max=100][,panic]`
    /// (or a JSON object string — see [`FaultPlan::from_json`]).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let s = s.trim();
        if s.is_empty() || s == "none" {
            return Ok(FaultPlan::none());
        }
        if s.starts_with('{') {
            let j = Json::parse(s).map_err(|e| anyhow::anyhow!("fault plan JSON: {e}"))?;
            return FaultPlan::from_json(&j);
        }
        let mut plan = FaultPlan { sites: FaultSite::ALL.to_vec(), ..FaultPlan::none() };
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if part == "panic" {
                plan.panic_in_batch = true;
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault plan: expected key=value, got {part:?}"))?;
            match k.trim() {
                "seed" => plan.seed = v.trim().parse()?,
                "rate" => plan.rate = v.trim().parse()?,
                "max" | "max_faults" => plan.max_faults = v.trim().parse()?,
                "sites" => {
                    plan.sites.clear();
                    for site in v.split('+').map(str::trim).filter(|s| !s.is_empty()) {
                        if site == "all" {
                            plan.sites = FaultSite::ALL.to_vec();
                        } else {
                            plan.sites.push(FaultSite::parse(site)?);
                        }
                    }
                }
                other => bail!("fault plan: unknown key {other:?}"),
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Parse the JSON-config form:
    /// `{"seed": 7, "rate": 0.05, "sites": ["engine_op", ...],
    ///   "max_faults": 100, "panic_in_batch": false}`.
    /// Omitted `sites` means all sites.
    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        let mut plan = FaultPlan { sites: FaultSite::ALL.to_vec(), ..FaultPlan::none() };
        if let Some(v) = j.get("seed").as_f64() {
            plan.seed = v as u64;
        }
        if let Some(v) = j.get("rate").as_f64() {
            plan.rate = v;
        }
        if let Some(v) = j.get("max_faults").as_f64() {
            plan.max_faults = v as u64;
        }
        if let Some(v) = j.get("panic_in_batch").as_bool() {
            plan.panic_in_batch = v;
        }
        if let Some(arr) = j.get("sites").as_arr() {
            plan.sites.clear();
            for s in arr {
                let name = s
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("fault plan sites must be strings"))?;
                if name == "all" {
                    plan.sites = FaultSite::ALL.to_vec();
                } else {
                    plan.sites.push(FaultSite::parse(name)?);
                }
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("rate", Json::num(self.rate)),
            (
                "sites",
                Json::arr(self.sites.iter().map(|s| Json::str(s.name()))),
            ),
            ("max_faults", Json::num(self.max_faults as f64)),
            ("panic_in_batch", Json::Bool(self.panic_in_batch)),
        ])
    }
}

// ---------------------------------------------------------------------
// Decision hash (SplitMix64-style finalizer, same family as util::rng)
// ---------------------------------------------------------------------

fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Combine two key components into one decision key (order-sensitive).
pub fn key2(a: u64, b: u64) -> u64 {
    mix(mix(a).wrapping_add(b))
}

/// Decision key for an engine op: `(request seed, attempt, op index)`.
/// Folding the attempt in means a retried run draws a *fresh* schedule —
/// without it, a deterministic injector would re-fail every replay of
/// the same op forever and retries could never succeed.
pub fn op_key(request_seed: u64, attempt: u64, op_index: u64) -> u64 {
    key2(key2(request_seed, attempt), op_index)
}

// ---------------------------------------------------------------------
// Injector
// ---------------------------------------------------------------------

/// Shared, thread-safe executor of a [`FaultPlan`]: pure per-site
/// decisions plus atomic injected-fault counters.  One lives inside the
/// `Engine` (engine_op / batch / kv sites) and one inside the server
/// (conn_io); both surface their totals through `faults_injected`.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    site_on: [bool; 4],
    injected: [AtomicU64; 4],
    total: AtomicU64,
    /// Monotonic key source for sites without a natural deterministic
    /// key (connection I/O events).
    conn_ctr: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let mut site_on = [false; 4];
        if !plan.is_none() {
            for s in &plan.sites {
                site_on[s.index()] = true;
            }
        }
        FaultInjector {
            plan,
            site_on,
            injected: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            total: AtomicU64::new(0),
            conn_ctr: AtomicU64::new(0),
        }
    }

    /// A permanently-disabled injector (plan [`FaultPlan::none`]).
    pub fn disabled() -> FaultInjector {
        FaultInjector::new(FaultPlan::none())
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// False for the inert plan — callers gate their site checks on
    /// this so the zero-fault path costs one branch.
    pub fn enabled(&self) -> bool {
        !self.plan.is_none()
    }

    /// Pure decision: would the plan fire at `site` for `key`?  Ignores
    /// the `max_faults` cap and mutates nothing (tests use this to
    /// predict schedules).
    pub fn decides(&self, site: FaultSite, key: u64) -> bool {
        if !self.site_on[site.index()] || self.plan.rate <= 0.0 {
            return false;
        }
        let h = mix(self.plan.seed ^ key2(site.index() as u64 + 1, key));
        // Top 53 bits → uniform in [0, 1); strict `<` keeps rate 0 silent.
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        u < self.plan.rate
    }

    /// Decide and count: true means a fault fires now (respecting the
    /// `max_faults` cap).
    pub fn should_inject(&self, site: FaultSite, key: u64) -> bool {
        if !self.decides(site, key) {
            return false;
        }
        if self.plan.max_faults > 0 {
            // Reserve a slot under the cap; back out on overshoot.
            let prev = self.total.fetch_add(1, Ordering::SeqCst);
            if prev >= self.plan.max_faults {
                self.total.fetch_sub(1, Ordering::SeqCst);
                return false;
            }
        } else {
            self.total.fetch_add(1, Ordering::SeqCst);
        }
        self.injected[site.index()].fetch_add(1, Ordering::SeqCst);
        true
    }

    /// Check-and-fail helper: `Err` with an `injected:` transient error
    /// when the plan fires at `site` for `key`, `Ok(())` otherwise.
    pub fn try_fault(&self, site: FaultSite, key: u64) -> Result<()> {
        if self.should_inject(site, key) {
            bail!("injected: {} fault (key {key:#018x})", site.name());
        }
        Ok(())
    }

    pub fn injected_total(&self) -> u64 {
        self.total.load(Ordering::SeqCst)
    }

    pub fn injected_at(&self, site: FaultSite) -> u64 {
        self.injected[site.index()].load(Ordering::SeqCst)
    }

    /// Next key for connection-I/O decisions (monotonic per process;
    /// deterministic for single-connection chaos runs).
    pub fn next_conn_key(&self) -> u64 {
        self.conn_ctr.fetch_add(1, Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_never_fires() {
        let inj = FaultInjector::disabled();
        assert!(!inj.enabled());
        for key in 0..1000 {
            for site in FaultSite::ALL {
                assert!(!inj.decides(site, key));
                assert!(inj.try_fault(site, key).is_ok());
            }
        }
        assert_eq!(inj.injected_total(), 0);
    }

    #[test]
    fn decisions_are_deterministic_and_seed_sensitive() {
        let a = FaultInjector::new(FaultPlan::all_sites(7, 0.3));
        let b = FaultInjector::new(FaultPlan::all_sites(7, 0.3));
        let c = FaultInjector::new(FaultPlan::all_sites(8, 0.3));
        let mut differs = false;
        for key in 0..500 {
            for site in FaultSite::ALL {
                assert_eq!(a.decides(site, key), b.decides(site, key));
                differs |= a.decides(site, key) != c.decides(site, key);
            }
        }
        assert!(differs, "seed change should alter the schedule");
    }

    #[test]
    fn rate_extremes_and_approximate_frequency() {
        let never = FaultInjector::new(FaultPlan::all_sites(3, 0.0));
        let always = FaultInjector::new(FaultPlan::all_sites(3, 1.0));
        let half = FaultInjector::new(FaultPlan::all_sites(3, 0.5));
        let mut hits = 0usize;
        for key in 0..10_000u64 {
            assert!(!never.decides(FaultSite::Kv, key));
            assert!(always.decides(FaultSite::Kv, key));
            if half.decides(FaultSite::Kv, key) {
                hits += 1;
            }
        }
        let frac = hits as f64 / 10_000.0;
        assert!((0.45..=0.55).contains(&frac), "rate 0.5 measured {frac}");
    }

    #[test]
    fn sites_gate_independently() {
        let plan = FaultPlan {
            seed: 11,
            rate: 1.0,
            sites: vec![FaultSite::Batch],
            ..FaultPlan::none()
        };
        let inj = FaultInjector::new(plan);
        assert!(inj.decides(FaultSite::Batch, 1));
        assert!(!inj.decides(FaultSite::EngineOp, 1));
        assert!(!inj.decides(FaultSite::Kv, 1));
        assert!(!inj.decides(FaultSite::ConnIo, 1));
    }

    #[test]
    fn max_faults_caps_total() {
        let plan = FaultPlan { max_faults: 3, ..FaultPlan::all_sites(5, 1.0) };
        let inj = FaultInjector::new(plan);
        let mut fired = 0;
        for key in 0..100 {
            if inj.should_inject(FaultSite::EngineOp, key) {
                fired += 1;
            }
        }
        assert_eq!(fired, 3);
        assert_eq!(inj.injected_total(), 3);
        assert_eq!(inj.injected_at(FaultSite::EngineOp), 3);
    }

    #[test]
    fn op_key_varies_with_attempt() {
        // A retried attempt must draw a fresh schedule: same (seed, op)
        // across attempts may not map to the same decision key.
        assert_ne!(op_key(42, 0, 3), op_key(42, 1, 3));
        assert_ne!(op_key(42, 0, 3), op_key(42, 0, 4));
        assert_eq!(op_key(42, 1, 3), op_key(42, 1, 3));
    }

    #[test]
    fn parse_compact_and_json_roundtrip() {
        let p = FaultPlan::parse("seed=7,rate=0.05,sites=engine_op+kv,max=10,panic").unwrap();
        assert_eq!(p.seed, 7);
        assert!((p.rate - 0.05).abs() < 1e-12);
        assert_eq!(p.sites, vec![FaultSite::EngineOp, FaultSite::Kv]);
        assert_eq!(p.max_faults, 10);
        assert!(p.panic_in_batch);

        let back = FaultPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);

        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::none());
        assert_eq!(FaultPlan::parse("none").unwrap(), FaultPlan::none());
        let all = FaultPlan::parse("seed=1,rate=0.1,sites=all").unwrap();
        assert_eq!(all.sites, FaultSite::ALL.to_vec());
        // Sites omitted ⇒ all sites.
        let dflt = FaultPlan::parse("seed=1,rate=0.1").unwrap();
        assert_eq!(dflt.sites, FaultSite::ALL.to_vec());
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(FaultPlan::parse("rate=1.5").is_err());
        assert!(FaultPlan::parse("sites=warp_core").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("seed").is_err());
    }
}
