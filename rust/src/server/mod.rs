//! TCP serving front end: newline-delimited JSON requests admitted
//! through the router shim into the continuous-batching scheduler
//! (see router.rs and `crate::scheduler`).
//!
//! Threading model (tokio is unavailable offline — DESIGN.md §3):
//! one accept loop + connection handlers submitted onto the
//! **process-wide work-stealing executor** ([`crate::exec`]) + one
//! scheduler composer thread that owns the engine and serves up to
//! `max_batch` in-flight sequences per step.  The composer's batched
//! engine passes ride the *same* executor via the scoped API, so serving
//! has exactly one worker substrate; [`Server::bind`] sizes it so the
//! blocking connection handlers (`io_threads.max(max_batch)` of them can
//! be parked awaiting replies) can never starve the engine's batch jobs
//! (`+ max_batch` headroom — and the composer helps run its own batch
//! jobs inline regardless, so progress never depends on a free worker).
//! At `max_batch = 1` this degenerates to the paper's deployment — a
//! single engine pass at a time, bit-identical metrics to the old
//! serial router.
//!
//! **Event forwarding is readiness-driven**: one dedicated pump thread
//! per server parks on a condvar and is woken by the scheduler-side
//! event waker the moment a streaming job emits an event — v2 frames
//! hit the wire at event latency instead of at the next
//! `stream_poll_ms` read-timeout tick.  `stream_poll_ms` survives only
//! as the pump's *fallback sweep* cadence (a safety net against a lost
//! wakeup), and `idle_poll_ms` as the handlers' read timeout for
//! observing shutdown.  The pump is a plain std thread, not an executor
//! worker: it parks indefinitely, and parked tasks must never occupy
//! the workers reserved for batched engine passes.

pub mod client;
pub mod protocol;
pub mod router;

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::DeployConfig;
use crate::exec::Executor;
use crate::faults::{FaultInjector, FaultSite};
use crate::scheduler::{code_of, ErrorCode, EventPoll, JobEvent, JobHandle, SubmitOpts};
pub use client::{StreamClient, WireEvent};
pub use protocol::{Op, QueryRequest, Request};
pub use router::{Router, RouterStats};

pub struct Server {
    listener: TcpListener,
    router: Arc<Router>,
    exec: Arc<Executor>,
    shutdown: Arc<AtomicBool>,
    /// Handlers accepted but not yet finished — the shared executor
    /// outlives the server, so [`Server::run`] drains this itself on
    /// shutdown (the retired per-server pool drained by being dropped).
    active_conns: Arc<AtomicUsize>,
    /// Max concurrent connection handlers (`io_threads.max(max_batch)`).
    /// The accept loop stops taking connections at this bound, so the
    /// executor always keeps the `+ max_batch` headroom free for batched
    /// engine passes no matter how many clients pile on.  Excess clients
    /// wait in the OS listen backlog — a *bounded* queue, unlike the
    /// retired handler pool's unbounded channel: past the backlog the OS
    /// refuses the connect outright.  That is a deliberate change —
    /// socket-level backpressure one layer below the admission queue's
    /// `rejected_overload`, instead of queueing idle sockets forever.
    handler_cap: usize,
    /// This server's share of [`RESERVED_HANDLERS`] (0 when its handlers
    /// ride a dedicated pool instead of the process-wide executor).
    reservation: usize,
    /// Per-connection handler context (poll cadences + the `conn_io`
    /// fault site), shared by every handler of this server.
    conn: Arc<ConnContext>,
    /// Wake-signal state shared between connection handlers, the
    /// scheduler-side event wakers, and the pump thread.
    pump: Arc<PumpShared>,
    /// The event-pump thread; joined in `Drop` after raising
    /// `PumpState::shutdown`.
    pump_thread: Option<std::thread::JoinHandle<()>>,
    pub addr: std::net::SocketAddr,
}

/// Poison-tolerant lock (the scheduler's helper, local to this module):
/// a panicking handler must not wedge the pump or every sibling
/// connection behind a poisoned mutex.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Connection-handler configuration: the read-timeout cadences promoted
/// from the old hardcoded constants (`DeployConfig::idle_poll_ms` /
/// `stream_poll_ms`), plus the server-side `conn_io` fault injector.
/// The injector is distinct from the engine's (which lives on the
/// scheduler's composer thread) but armed from the same
/// `DeployConfig::fault_plan`; the `stats` op merges both counters.
struct ConnContext {
    /// Handler read-timeout cadence (observes the shutdown flag): a
    /// handler parked on an *idle* connection must not occupy an
    /// executor worker past shutdown.  Event forwarding no longer rides
    /// this tick — the pump thread is woken per event.
    idle_read: Duration,
    /// The pump thread's *fallback sweep* cadence: how long it parks on
    /// its condvar before sweeping every connection anyway.  Wakeups
    /// make forwarding event-latency; the sweep only bounds the damage
    /// of a hypothetical lost wakeup.
    stream_read: Duration,
    faults: FaultInjector,
}

impl ConnContext {
    /// `conn_io`-site fault gate: consulted once per processed request
    /// line and once per streamed frame.  A fired fault errors the
    /// connection handler — the connection drops (like a mid-stream
    /// network failure), its unfinished session handles drop, and their
    /// `Drop` cancels the scheduler-side jobs.  The server itself keeps
    /// accepting.  Inert (one branch) without an armed plan.
    fn io_fault(&self) -> Result<()> {
        if self.faults.enabled() {
            self.faults.try_fault(FaultSite::ConnIo, self.faults.next_conn_key())?;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        RESERVED_HANDLERS.fetch_sub(self.reservation, Ordering::SeqCst);
        // Stop the pump after `run` drained the handlers (each handler's
        // unregister guard has removed its connection by then).
        {
            let mut st = lock(&self.pump.state);
            st.shutdown = true;
        }
        self.pump.cv.notify_all();
        if let Some(t) = self.pump_thread.take() {
            let _ = t.join();
        }
    }
}

/// Shared between handlers (register/unregister, session tables), the
/// scheduler-side event wakers (ready-queue pushes), and the pump
/// thread (condvar waits, frame writes).
///
/// Lock order (acyclic): a connection's `ConnEntry::streams` may be
/// held while installing a waker (scheduler waker-slot lock) or firing
/// one (`PumpShared::state`); the pump takes `state` *scoped* — released
/// before any `streams` lock — so no path orders `state` before
/// `streams` while holding it.
struct PumpShared {
    state: Mutex<PumpState>,
    cv: Condvar,
}

struct PumpState {
    /// Registered connections by pump-assigned id.
    conns: BTreeMap<u64, Arc<ConnEntry>>,
    /// Connections with (potentially) ready events, in wakeup order.
    ready: VecDeque<u64>,
    next_id: u64,
    shutdown: bool,
}

/// One registered connection: its live v2 sessions plus the write half.
/// Every wire write — pump frames *and* handler responses — goes
/// through the `streams` lock, so concurrently produced NDJSON lines
/// never interleave mid-line on the socket.
struct ConnEntry {
    id: u64,
    streams: Mutex<ConnStreams>,
}

struct ConnStreams {
    sessions: Vec<StreamSession>,
    writer: TcpStream,
    /// Set by the pump when a write or injected `conn_io` fault killed
    /// the connection; the socket is shut down so the handler's blocked
    /// read returns EOF instead of lingering until its next timeout.
    dead: bool,
}

/// Register a new connection with the pump; the returned entry carries
/// the connection's session table and serialized writer.
fn register_conn(pump: &PumpShared, writer: TcpStream) -> Arc<ConnEntry> {
    let mut st = lock(&pump.state);
    let id = st.next_id;
    st.next_id += 1;
    let entry = Arc::new(ConnEntry {
        id,
        streams: Mutex::new(ConnStreams { sessions: Vec::new(), writer, dead: false }),
    });
    st.conns.insert(id, Arc::clone(&entry));
    entry
}

/// Drop guard: unregisters the connection on every handler exit path
/// (EOF, shutdown, error, panic).  The entry — and with it any
/// unfinished session handles, whose `Drop` cancels the scheduler-side
/// jobs — is released *outside* the pump state lock.
struct ConnUnregister {
    pump: Arc<PumpShared>,
    id: u64,
}

impl Drop for ConnUnregister {
    fn drop(&mut self) {
        let entry = {
            let mut st = lock(&self.pump.state);
            st.conns.remove(&self.id)
        };
        drop(entry);
    }
}

/// Write one NDJSON line through the connection's serialized writer.
fn write_line(entry: &ConnEntry, line: &str) -> Result<()> {
    let mut s = lock(&entry.streams);
    anyhow::ensure!(!s.dead, "connection closed by stream pump");
    s.writer.write_all(line.as_bytes())?;
    s.writer.write_all(b"\n")?;
    s.writer.flush()?;
    Ok(())
}

/// The pump thread: park on the condvar until an event waker flags a
/// connection ready (or the fallback sweep tick fires), then forward
/// that connection's ready events.  The ready batch is collected under
/// the state lock in a scoped block and pumped after release — the
/// per-connection work never runs under the global lock.
fn pump_loop(shared: &PumpShared, conn: &ConnContext) {
    loop {
        let batch: Vec<Arc<ConnEntry>>;
        {
            let mut st = lock(&shared.state);
            if st.ready.is_empty() && !st.shutdown {
                let (guard, _timeout) = shared
                    .cv
                    .wait_timeout(st, conn.stream_read)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
                st = guard;
            }
            if st.shutdown {
                break;
            }
            if st.ready.is_empty() {
                // Fallback sweep: no wakeup before the tick — service
                // everything (almost always a no-op per connection).
                batch = st.conns.values().cloned().collect();
            } else {
                let mut ids: Vec<u64> = st.ready.drain(..).collect();
                ids.dedup();
                batch = ids.into_iter().filter_map(|id| st.conns.get(&id).cloned()).collect();
            }
        }
        for entry in &batch {
            pump_conn(entry, conn);
        }
    }
}

/// Forward one connection's ready events; on a write error or injected
/// `conn_io` fault, kill the connection (mark dead, shut the socket so
/// the handler's read unblocks, drop the sessions so their jobs cancel).
fn pump_conn(entry: &ConnEntry, conn: &ConnContext) {
    let mut s = lock(&entry.streams);
    if s.dead {
        return;
    }
    let ConnStreams { sessions, writer, dead } = &mut *s;
    if let Err(e) = pump_sessions(sessions, writer, conn) {
        *dead = true;
        writer.shutdown(Shutdown::Both).ok();
        sessions.clear();
        eprintln!("[server] stream pump: connection dropped: {e:#}");
    }
}

/// Handler-worker capacity reserved on the *process-wide* executor
/// across every live server in this process.  Each server's accept loop
/// honors its own `handler_cap`, but two servers sharing one pool could
/// still jointly park enough handlers to occupy the batch headroom — the
/// ledger makes that joint demand visible so a late-binding server falls
/// back to a dedicated handler pool instead of breaking the
/// no-starvation floor.  Released in `Drop for Server`.
static RESERVED_HANDLERS: AtomicUsize = AtomicUsize::new(0);

/// Decrement-on-drop guard so a handler is always un-counted, even if it
/// panics (the worker's `catch_unwind` still runs this drop).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Server {
    /// Bind and start the engine. Use `addr = "127.0.0.1:0"` for an
    /// ephemeral port (tests).
    pub fn bind(mut cfg: DeployConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        // Each connection handler blocks for its in-flight query, so the
        // executor needs at least io_threads.max(max_batch) workers for
        // handlers (fewer would cap batch occupancy below max_batch
        // regardless of client concurrency) plus max_batch headroom so
        // the composer's batched engine passes always find free workers
        // even when every handler slot is parked on a reply.
        let mut exec_cfg = cfg.exec.clone();
        let handler_cap = cfg.io_threads.max(cfg.max_batch);
        // Headroom scales with the replica count: each replica's
        // composer submits its own batched engine passes (a composer
        // always helps run its own jobs inline, so this is throughput
        // headroom, not a liveness requirement).  At `replicas = 1`
        // this is the historical floor exactly.
        let floor = handler_cap + cfg.max_batch * cfg.replicas.max(1);
        let resolved = exec_cfg.resolve_workers()?;
        exec_cfg.workers = Some(resolved.max(floor));
        // Log the raise only when this call actually creates the pool —
        // with a pre-existing global (first-config-wins) the request is
        // ignored and configure_global/the fallback below report that.
        let preexisting = crate::exec::global_if_initialized().is_some();
        let exec = crate::exec::configure_global(&exec_cfg)?;
        if resolved < floor && !preexisting {
            eprintln!(
                "[server] raising executor workers {resolved} -> {floor} \
                 (io_threads/max_batch floor; lower io_threads or max_batch to shrink)"
            );
        }
        // Hand the resolved sizing down so Router::start's own
        // configure_global (the direct-embedder path) agrees with the
        // pool just built instead of re-requesting the pre-floor size.
        cfg.exec = exec_cfg;
        // Captured before Router::start consumes the config.
        let conn = Arc::new(ConnContext {
            idle_read: Duration::from_millis(cfg.idle_poll_ms),
            stream_read: Duration::from_millis(cfg.stream_poll_ms),
            faults: FaultInjector::new(cfg.fault_plan.clone()),
        });
        // Boot the scheduler before taking a reservation: Router::start
        // can fail (bad artifacts), and a reservation taken first would
        // leak — Drop for Server is the only release path.
        let router = Arc::new(Router::start(cfg)?);
        // configure_global is first-config-wins; if another consumer
        // already built a smaller pool (an embedder) — or other live
        // servers' handlers have already reserved part of this one
        // (RESERVED_HANDLERS) — handlers on it could occupy every worker
        // and starve batch passes down to composer-helping speed.  Keep
        // the no-starvation guarantee by giving *this* server's handlers
        // a dedicated pool of the same substrate instead; engine batches
        // still ride the shared executor.
        let reserved = RESERVED_HANDLERS.fetch_add(floor, Ordering::SeqCst) + floor;
        let (exec, reservation) = if exec.workers() < reserved {
            RESERVED_HANDLERS.fetch_sub(floor, Ordering::SeqCst);
            eprintln!(
                "[server] process-wide executor has {} workers, {} already \
                 reserved by other servers (< floor {floor}); using a \
                 dedicated {floor}-worker handler pool",
                exec.workers(),
                reserved - floor
            );
            (Arc::new(Executor::new(floor)), 0)
        } else {
            (exec, floor)
        };
        let pump = Arc::new(PumpShared {
            state: Mutex::new(PumpState {
                conns: BTreeMap::new(),
                ready: VecDeque::new(),
                next_id: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let pump_thread = {
            let shared = Arc::clone(&pump);
            let pconn = Arc::clone(&conn);
            std::thread::Builder::new()
                .name("server-pump".into())
                .spawn(move || pump_loop(&shared, &pconn))
                .context("spawning the event-pump thread")?
        };
        Ok(Server {
            listener,
            router,
            exec,
            shutdown: Arc::new(AtomicBool::new(false)),
            active_conns: Arc::new(AtomicUsize::new(0)),
            handler_cap,
            reservation,
            conn,
            pump: Arc::clone(&pump),
            pump_thread: Some(pump_thread),
            addr,
        })
    }

    /// Serve until a `shutdown` op arrives. Blocks.
    pub fn run(self) -> Result<()> {
        let result = self.accept_loop();
        // Whatever ended the accept loop — shutdown op, closed executor,
        // or a hard accept error — raise the flag so idle handlers
        // (polling it every read-timeout tick) terminate instead of
        // occupying executor workers indefinitely, then drain.
        self.shutdown.store(true, Ordering::SeqCst);
        // Drain in-flight handlers before returning (the retired
        // per-server pool did this in Drop).  Idle handlers observe the
        // shutdown flag within one read-timeout tick (`idle_poll_ms`,
        // 200 ms by default); handlers
        // awaiting a reply exit once their query completes.  The
        // deadline only triggers for queries still running after 30 s —
        // those handlers finish (and free their worker) when the
        // scheduler completes or fails the query during Router drop.
        let deadline = Instant::now() + Duration::from_secs(30);
        while self.active_conns.load(Ordering::SeqCst) > 0 {
            if Instant::now() >= deadline {
                eprintln!(
                    "[server] shutdown: leaving {} in-flight handler(s) to finish \
                     with their queries",
                    self.active_conns.load(Ordering::SeqCst)
                );
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        result
    }

    fn accept_loop(&self) -> Result<()> {
        // Accept-loop wakeups for shutdown: set a small timeout via
        // nonblocking accept + sleep (portable without mio).
        self.listener.set_nonblocking(true)?;
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            // Handler-concurrency bound: beyond handler_cap in-flight
            // connections, stop accepting (clients wait in the OS
            // backlog) so parked handlers can never occupy the workers
            // reserved for batched engine passes.
            if self.active_conns.load(Ordering::SeqCst) >= self.handler_cap {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let router = Arc::clone(&self.router);
                    let shutdown = Arc::clone(&self.shutdown);
                    // Counted before submission so the drain below can
                    // never miss a handler that is queued but not yet
                    // running.
                    self.active_conns.fetch_add(1, Ordering::SeqCst);
                    let guard = ConnGuard(Arc::clone(&self.active_conns));
                    let exec = Arc::clone(&self.exec);
                    let conn = Arc::clone(&self.conn);
                    let pump = Arc::clone(&self.pump);
                    let submitted = self.exec.execute_labeled("server:conn", move || {
                        let _guard = guard;
                        if let Err(e) =
                            handle_connection(stream, &router, &exec, &shutdown, &conn, &pump)
                        {
                            eprintln!("[server] connection error: {e:#}");
                        }
                    });
                    if submitted.is_err() {
                        // Executor closed under us — treat like shutdown;
                        // run() raises the flag and drains.  (The
                        // rejected closure was dropped, running its
                        // guard, so the count stays balanced.)
                        eprintln!("[server] executor closed; stopping accept loop");
                        break;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

/// Hard cap on one request line.  A client streaming bytes without a
/// newline must not grow server memory unboundedly — handlers share the
/// process with every sweep/batch consumer.
const MAX_LINE_BYTES: usize = 1 << 20;

/// One non-blocking(ish) attempt to complete a request line.
enum LinePoll {
    Line(String),
    /// No complete line yet (read timed out); partial bytes stay in
    /// `buf` for the next poll.
    Pending,
    Eof,
}

/// Pull at most one line from the socket, returning [`LinePoll::Pending`]
/// on a read-timeout tick so the caller can interleave stream pumping
/// and shutdown checks.  Bounded fills: the cap check runs even against
/// a client streaming continuously without a newline (std `read_until`
/// would not return — and a cap could never fire — until the delimiter
/// arrives).
fn poll_line(reader: &mut BufReader<TcpStream>, buf: &mut Vec<u8>) -> Result<LinePoll> {
    loop {
        let (complete, used) = match reader.fill_buf() {
            Ok([]) => {
                // EOF.  A final unterminated line (buffered by earlier
                // polls) is still served, as BufRead::lines did; the
                // next call reads zero bytes into an empty buf → Eof.
                if buf.is_empty() {
                    return Ok(LinePoll::Eof);
                }
                (true, 0)
            }
            Ok(chunk) => match chunk.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    buf.extend_from_slice(&chunk[..=i]);
                    (true, i + 1)
                }
                None => {
                    buf.extend_from_slice(chunk);
                    (false, chunk.len())
                }
            },
            // Interrupted (EINTR) is retried like a timeout tick —
            // BufRead::read_until did that internally; a signal must not
            // kill a healthy connection.
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Ok(LinePoll::Pending);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        reader.consume(used);
        anyhow::ensure!(
            buf.len() <= MAX_LINE_BYTES + 1, // +1: the delimiter itself
            "request line exceeds {MAX_LINE_BYTES} bytes"
        );
        if complete {
            // Strip the delimiter (and a CR) like BufRead::lines did.
            if buf.last() == Some(&b'\n') {
                buf.pop();
            }
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            let line = utf8_line(buf)?;
            buf.clear();
            return Ok(LinePoll::Line(line));
        }
    }
}

/// UTF-8-validate a received line, erroring like `BufRead::lines` did
/// (no lossy replacement — garbage bytes must not turn into a
/// plausible-looking request).
fn utf8_line(buf: &[u8]) -> Result<String> {
    std::str::from_utf8(buf)
        .map(str::to_owned)
        .map_err(|e| anyhow::anyhow!("request line is not valid UTF-8: {e}"))
}

/// One in-flight v2 streaming session on a connection: the client's wire
/// id plus the scheduler-side handle whose events are forwarded as
/// NDJSON frames.
struct StreamSession {
    wire_id: i64,
    handle: JobHandle,
}

/// Forward every ready event of every live session to the wire, retiring
/// sessions at their terminal frame.  Returns with `Pending` streams
/// intact; the pump re-runs this on the connection's next wakeup (or
/// fallback sweep).  Caller holds the connection's `streams` lock.
fn pump_sessions(
    sessions: &mut Vec<StreamSession>,
    writer: &mut TcpStream,
    conn: &ConnContext,
) -> Result<()> {
    let mut wrote = false;
    let mut i = 0;
    while i < sessions.len() {
        let mut done = false;
        loop {
            match sessions[i].handle.poll_event() {
                EventPoll::Event(ev) => {
                    let terminal = ev.is_terminal();
                    let frame = protocol::event_frame(sessions[i].wire_id, &ev);
                    conn.io_fault()?;
                    writer.write_all(frame.as_bytes())?;
                    writer.write_all(b"\n")?;
                    wrote = true;
                    if terminal {
                        done = true;
                        break;
                    }
                }
                EventPoll::Pending => break,
                EventPoll::Disconnected => {
                    // The composer died without a terminal event — the
                    // stream analogue of v1's "engine worker dropped".
                    let frame = protocol::error_frame(
                        sessions[i].wire_id,
                        ErrorCode::Shutdown,
                        "engine worker dropped",
                    );
                    writer.write_all(frame.as_bytes())?;
                    writer.write_all(b"\n")?;
                    wrote = true;
                    done = true;
                    break;
                }
            }
        }
        if done {
            sessions.remove(i);
        } else {
            i += 1;
        }
    }
    if wrote {
        writer.flush()?;
    }
    Ok(())
}

fn handle_connection(
    stream: TcpStream,
    router: &Router,
    exec: &Executor,
    shutdown: &AtomicBool,
    conn: &ConnContext,
    pump: &Arc<PumpShared>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(conn.idle_read))?;
    let writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    // Register with the event pump.  This connection's v2 sessions live
    // in the shared entry — the pump forwards their frames the moment
    // the scheduler emits an event — and every write (frames *and*
    // responses) is serialized through it.  Cancellation stays scoped to
    // this connection: a `cancel` op can only target the entry's own
    // sessions, and the unregister guard drops unfinished handles on
    // every exit path — EOF, shutdown, error, panic — whose Drop
    // cancels the scheduler-side job (a vanished client must not keep
    // consuming engine time).
    let entry = register_conn(pump, writer);
    let _unregister = ConnUnregister { pump: Arc::clone(pump), id: entry.id };
    // An awaited v1 one-shot query.  While set, no further requests are
    // read (v1 responses stay strictly ordered with their requests, as
    // the pre-streaming server guaranteed); live v2 streams keep
    // flowing regardless — they are the pump thread's job now.
    let mut v1_pending: Option<(i64, JobHandle)> = None;
    loop {
        if let Some((rid, handle)) = v1_pending.take() {
            // The channel recv is itself readiness-driven (it wakes on
            // event arrival); the timeout only bounds how long shutdown
            // can go unobserved.
            let response = match handle.next_event_timeout(conn.idle_read) {
                Ok(JobEvent::Result(result)) => Some(protocol::ok_response(
                    rid,
                    protocol::job_result_to_json(&result),
                )),
                Ok(JobEvent::Error(e)) => {
                    Some(protocol::error_response(rid, &format!("{e:#}")))
                }
                Ok(JobEvent::Cancelled) => {
                    Some(protocol::error_response(rid, "request cancelled"))
                }
                // Lifecycle events of the one-shot drain silently, just
                // as the old blocking fold did.
                Ok(_) => None,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    Some(protocol::error_response(rid, "engine worker dropped"))
                }
            };
            match response {
                Some(response) => {
                    write_line(&entry, &response)?;
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                }
                None => v1_pending = Some((rid, handle)),
            }
            continue;
        }
        let line = match poll_line(&mut reader, &mut buf)? {
            LinePoll::Eof => break,
            LinePoll::Pending => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            LinePoll::Line(line) => line,
        };
        if line.trim().is_empty() {
            continue;
        }
        // One conn_io fault opportunity per processed request line (the
        // request is "lost in transit": the connection drops before it
        // reaches the router, like a mid-request network failure).
        conn.io_fault()?;
        // `None` response: a v2 query became a session; its frames flow
        // from pump_sessions.
        let response = match Request::parse(&line) {
            Err(e) => {
                // v1 keeps the old lenient error reply (id 0); v2 gets a
                // structured bad_request frame addressed to the request.
                let (pid, pv) = Request::peek_meta(&line);
                if pv >= 2 {
                    Some(protocol::error_frame(pid, ErrorCode::BadRequest, &format!("{e:#}")))
                } else {
                    Some(protocol::error_response(0, &format!("{e:#}")))
                }
            }
            Ok(req) => match req.op {
                Op::Ping => {
                    Some(protocol::ok_response(req.id, crate::util::json::Json::str("pong")))
                }
                Op::Stats => {
                    // "exec" (set by stats_json) stays the process-wide
                    // executor — that is where the engine's batch jobs
                    // (and their panic telemetry) live.  When Server::bind
                    // fell back to a dedicated handler pool, report it
                    // alongside rather than over the top, so neither
                    // pool's counters mask the other's.
                    let mut j = router.stats_json();
                    let on_global = crate::exec::global_if_initialized()
                        .is_some_and(|g| std::ptr::eq(Arc::as_ptr(&g), exec));
                    if !on_global {
                        j.set("handler_exec", exec.stats().to_json());
                    }
                    // "faults_injected" totals the whole serving path:
                    // the scheduler publishes the engine-side sites
                    // (engine_op / batch / kv); conn_io fires in the
                    // handlers, so its count merges here.
                    let conn_faults = conn.faults.injected_total();
                    if conn_faults > 0 {
                        let total = j.get("faults_injected").as_f64().unwrap_or(0.0)
                            + conn_faults as f64;
                        j.set("faults_injected", crate::util::json::Json::num(total));
                    }
                    Some(protocol::ok_response(req.id, j))
                }
                Op::Shutdown => {
                    shutdown.store(true, Ordering::SeqCst);
                    Some(protocol::ok_response(req.id, crate::util::json::Json::str("bye")))
                }
                Op::Metrics => Some(protocol::ok_response(req.id, router.metrics_json())),
                Op::Trace { target } => {
                    Some(protocol::ok_response(req.id, router.trace_json(target)))
                }
                Op::Cancel { target } => {
                    // Scoped to this connection's sessions by
                    // construction; the ack reports whether the target
                    // was found in flight and cancellation *requested*.
                    // The terminal frame (via the pump) is `cancelled`
                    // unless the job wins the race by completing in the
                    // scheduler tick already in progress — then it is
                    // `result`.
                    let found = {
                        let s = lock(&entry.streams);
                        match s.sessions.iter().find(|x| x.wire_id == target) {
                            Some(x) => {
                                x.handle.cancel();
                                true
                            }
                            None => false,
                        }
                    };
                    Some(protocol::ok_response(
                        req.id,
                        crate::util::json::Json::obj(vec![(
                            "cancelled",
                            crate::util::json::Json::Bool(found),
                        )]),
                    ))
                }
                Op::Query(q) if req.v >= 2 => {
                    let dup = {
                        let s = lock(&entry.streams);
                        s.sessions.iter().any(|x| x.wire_id == req.id)
                    };
                    if dup {
                        Some(protocol::error_frame(
                            req.id,
                            ErrorCode::BadRequest,
                            "duplicate id: a stream with this id is in flight on this connection",
                        ))
                    } else {
                        match router.submit_with(q, SubmitOpts { deadline_ms: req.deadline_ms }) {
                            Err(e) => Some(protocol::error_frame(
                                req.id,
                                code_of(&e),
                                &format!("{e:#}"),
                            )),
                            Ok(handle) => {
                                // Session enters the table first, *then*
                                // the waker is installed — set_waker
                                // fires once on install, so events that
                                // raced ahead of registration are
                                // pumped, not stranded until the
                                // fallback sweep.
                                let mut s = lock(&entry.streams);
                                s.sessions.push(StreamSession { wire_id: req.id, handle });
                                let shared = Arc::clone(pump);
                                let conn_id = entry.id;
                                s.sessions
                                    .last()
                                    .expect("session just pushed")
                                    .handle
                                    .set_waker(Box::new(move || {
                                        {
                                            let mut st = lock(&shared.state);
                                            st.ready.push_back(conn_id);
                                        }
                                        shared.cv.notify_one();
                                    }));
                                None
                            }
                        }
                    }
                }
                // v1 one-shot query: await the terminal result before
                // reading further requests (the pre-streaming ordering
                // contract, with bit-identical response bytes) — but via
                // the pending-state fold above, so concurrent v2 streams
                // on this connection keep receiving frames meanwhile.
                Op::Query(q) => match router.submit(q) {
                    Err(e) => Some(protocol::error_response(req.id, &format!("{e:#}"))),
                    Ok(handle) => {
                        v1_pending = Some((req.id, handle));
                        None
                    }
                },
            },
        };
        if let Some(response) = response {
            write_line(&entry, &response)?;
        }
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send a raw op object (fields besides id) and return the response.
    pub fn call(&mut self, mut body: crate::util::json::Json) -> Result<crate::util::json::Json> {
        let id = self.next_id;
        self.next_id += 1;
        body.set("id", crate::util::json::Json::num(id as f64));
        self.writer.write_all(body.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = crate::util::json::Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if resp.get("ok").as_bool() != Some(true) {
            anyhow::bail!(
                "server error: {}",
                resp.get("error").as_str().unwrap_or("unknown")
            );
        }
        Ok(resp.get("result").clone())
    }

    pub fn ping(&mut self) -> Result<()> {
        use crate::util::json::Json;
        let r = self.call(Json::obj(vec![("op", Json::str("ping"))]))?;
        anyhow::ensure!(r.as_str() == Some("pong"), "unexpected ping reply");
        Ok(())
    }
}
