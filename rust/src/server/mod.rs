//! TCP serving front end: newline-delimited JSON requests admitted
//! through the router shim into the continuous-batching scheduler
//! (see router.rs and `crate::scheduler`).
//!
//! Threading model (tokio is unavailable offline — DESIGN.md §3):
//! one accept loop + a fixed [`ThreadPool`](crate::util::threadpool) of
//! connection handlers + one scheduler composer thread that owns the
//! engine and serves up to `max_batch` in-flight sequences per step.
//! At `max_batch = 1` this degenerates to the paper's deployment — a
//! single engine pass at a time, bit-identical metrics to the old
//! serial router.

pub mod protocol;
pub mod router;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::DeployConfig;
use crate::util::threadpool::ThreadPool;
pub use protocol::{Op, QueryRequest, Request};
pub use router::{Router, RouterStats};

pub struct Server {
    listener: TcpListener,
    router: Arc<Router>,
    pool: ThreadPool,
    shutdown: Arc<AtomicBool>,
    pub addr: std::net::SocketAddr,
}

impl Server {
    /// Bind and start the engine. Use `addr = "127.0.0.1:0"` for an
    /// ephemeral port (tests).
    pub fn bind(cfg: DeployConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        // Each connection handler blocks for its in-flight query, so
        // fewer handlers than batch slots would cap batch occupancy
        // below max_batch regardless of client concurrency.
        let io_threads = cfg.io_threads.max(cfg.max_batch);
        let router = Arc::new(Router::start(cfg)?);
        Ok(Server {
            listener,
            router,
            pool: ThreadPool::new(io_threads),
            shutdown: Arc::new(AtomicBool::new(false)),
            addr,
        })
    }

    /// Serve until a `shutdown` op arrives. Blocks.
    pub fn run(self) -> Result<()> {
        // Accept-loop wakeups for shutdown: set a small timeout via
        // nonblocking accept + sleep (portable without mio).
        self.listener.set_nonblocking(true)?;
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let router = Arc::clone(&self.router);
                    let shutdown = Arc::clone(&self.shutdown);
                    let submitted = self.pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &router, &shutdown) {
                            eprintln!("[server] connection error: {e:#}");
                        }
                    });
                    if submitted.is_err() {
                        // Pool closed under us — treat like shutdown.
                        eprintln!("[server] connection pool closed; stopping accept loop");
                        break;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

fn handle_connection(
    stream: TcpStream,
    router: &Router,
    shutdown: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Err(e) => protocol::error_response(0, &format!("{e:#}")),
            Ok(req) => match req.op {
                Op::Ping => protocol::ok_response(req.id, crate::util::json::Json::str("pong")),
                Op::Stats => protocol::ok_response(req.id, router.stats_json()),
                Op::Shutdown => {
                    shutdown.store(true, Ordering::SeqCst);
                    protocol::ok_response(req.id, crate::util::json::Json::str("bye"))
                }
                Op::Query(q) => match router.submit(q) {
                    Err(e) => protocol::error_response(req.id, &format!("{e:#}")),
                    Ok(rx) => match rx.recv() {
                        Ok(Ok(result)) => {
                            protocol::ok_response(req.id, router::job_result_to_json(&result))
                        }
                        Ok(Err(e)) => protocol::error_response(req.id, &format!("{e:#}")),
                        Err(_) => protocol::error_response(req.id, "engine worker dropped"),
                    },
                },
            },
        };
        writer.write_all(response.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send a raw op object (fields besides id) and return the response.
    pub fn call(&mut self, mut body: crate::util::json::Json) -> Result<crate::util::json::Json> {
        let id = self.next_id;
        self.next_id += 1;
        body.set("id", crate::util::json::Json::num(id as f64));
        self.writer.write_all(body.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = crate::util::json::Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if resp.get("ok").as_bool() != Some(true) {
            anyhow::bail!(
                "server error: {}",
                resp.get("error").as_str().unwrap_or("unknown")
            );
        }
        Ok(resp.get("result").clone())
    }

    pub fn ping(&mut self) -> Result<()> {
        use crate::util::json::Json;
        let r = self.call(Json::obj(vec![("op", Json::str("ping"))]))?;
        anyhow::ensure!(r.as_str() == Some("pong"), "unexpected ping reply");
        Ok(())
    }
}
