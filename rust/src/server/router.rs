//! Request router: a thin admission shim over the replica fleet
//! ([`ReplicaRouter`] — N continuous-batching
//! [`Scheduler`](crate::scheduler::Scheduler)s behind prefix-affinity
//! placement; one replica by default, a transparent delegation).
//!
//! The router's job shrank to protocol-level concerns: resolve a wire
//! [`QueryRequest`] against the deployment defaults into a fully-specified
//! [`JobRequest`], submit it (placement picks the replica; each
//! scheduler enforces the `max_queue` backpressure bound, KV-aware
//! admission, batching and preemption), and render results/stats as
//! JSON.  Connection handlers only parse and serialize; the engines
//! live inside the schedulers' composer threads.

use anyhow::Result;

use crate::config::DeployConfig;
use crate::coordinator::AcceptancePolicy;
use crate::scheduler::replica::ReplicaRouter;
use crate::scheduler::{JobHandle, JobRequest, SubmitOpts};
use crate::server::protocol::QueryRequest;
use crate::util::json::Json;

pub use crate::scheduler::RouterStats;
pub use crate::server::protocol::job_result_to_json;

pub struct Router {
    fleet: ReplicaRouter,
    cfg: DeployConfig,
}

impl Router {
    /// Boot the replica fleet (each scheduler loads its engine on its
    /// composer thread); startup errors propagate here.
    pub fn start(cfg: DeployConfig) -> Result<Router> {
        // Direct embedders reach here without `Server::bind`/`specreason
        // run` having sized the process-wide executor — apply the deploy
        // config's exec knobs ("threads"/"pin") now so they are never
        // silently ignored.  First-config-wins makes this a no-op when
        // the server already configured a (floored) pool.
        crate::exec::configure_global(&cfg.exec)?;
        let fleet = ReplicaRouter::start(cfg.clone())?;
        Ok(Router { fleet, cfg })
    }

    /// Try to admit a query; `Err` means backpressure (`overloaded`).
    /// The returned [`JobHandle`] streams the job's lifecycle events; v1
    /// one-shot callers fold it with [`JobHandle::recv`].
    pub fn submit(&self, req: QueryRequest) -> Result<JobHandle> {
        self.fleet.submit(self.resolve(&req))
    }

    /// [`submit`](Self::submit) with per-request options (the v2 path's
    /// enforced `deadline_ms`).
    pub fn submit_with(&self, req: QueryRequest, opts: SubmitOpts) -> Result<JobHandle> {
        self.fleet.submit_with(self.resolve(&req), opts)
    }

    /// Apply per-request overrides onto the deployment defaults.
    fn resolve(&self, req: &QueryRequest) -> JobRequest {
        let mut spec = self.cfg.spec_config();
        if let Some(s) = req.scheme {
            spec.scheme = s;
        }
        if let Some(t) = req.threshold {
            spec.policy = AcceptancePolicy::Static { threshold: t };
        }
        if let Some(n) = req.first_n_base {
            spec.first_n_base = n;
        }
        if let Some(b) = req.budget {
            spec.token_budget = b;
        }
        JobRequest {
            dataset: req.dataset,
            query_index: req.query_index,
            sample: req.sample,
            seed: req.seed.unwrap_or(self.cfg.seed),
            spec,
            priority: req.priority.unwrap_or_default(),
        }
    }

    pub fn stats(&self) -> RouterStats {
        self.fleet.stats()
    }

    /// Serving counters plus, when the process-wide executor exists, an
    /// `"exec"` object with its queue-depth / steal / utilization
    /// counters and the last captured worker panic (label + payload
    /// message) — swallowed worker panics are diagnosable from here,
    /// not just a stderr line.  (When `Server::bind` fell back to a
    /// dedicated handler pool, the server's `stats` op adds a separate
    /// `"handler_exec"` object for it — `"exec"` always stays the
    /// process-wide executor carrying the engine's batch jobs.)
    pub fn stats_json(&self) -> Json {
        let mut j = self.stats().to_json();
        if let Some(exec) = crate::exec::global_if_initialized() {
            j.set("exec", exec.stats().to_json());
        }
        // Latency quantiles from the always-on registry histograms —
        // additive next to the existing mean fields (`queue_wait_s_mean`
        // / `ttfs_s_mean` / `ttfe_s_mean` keep their exact meaning).
        // At `replicas > 1` the quantiles come from *merged* buckets
        // (typed fold), not averaged per-replica summaries.
        let mut latency = Json::obj(vec![]);
        for (key, hist) in [
            ("queue_wait_s", "scheduler.queue_wait_s"),
            ("ttfs_s", "scheduler.ttfs_s"),
            ("ttfe_s", "scheduler.ttfe_s"),
            ("e2e_s", "scheduler.e2e_s"),
        ] {
            if let Some((p50, p95, p99)) = self.fleet.quantiles(hist) {
                latency.set(
                    key,
                    Json::obj(vec![
                        ("p50", Json::num(p50)),
                        ("p95", Json::num(p95)),
                        ("p99", Json::num(p99)),
                    ]),
                );
            }
        }
        j.set("latency", latency);
        // Per-replica breakdown, only when there is more than one
        // replica — the single-replica payload stays byte-identical.
        if self.fleet.replica_count() > 1 {
            j.set(
                "replicas",
                Json::arr(self.fleet.replica_stats().iter().map(RouterStats::to_json)),
            );
        }
        j
    }

    /// The `metrics` op payload: full registry dump (counters, gauges,
    /// histograms with p50/p95/p99), flight-recorder state, trace
    /// counts.  Merged bucket-wise across replicas at `replicas > 1`.
    pub fn metrics_json(&self) -> Json {
        self.fleet.metrics_json()
    }

    /// The `trace` op payload: one traced timeline (`target`, or the
    /// most recently finished), `null` when tracing is off or nothing
    /// matches.  Looked up on whichever replica served the trace.
    pub fn trace_json(&self, target: Option<u64>) -> Json {
        self.fleet.trace_json(target)
    }

    /// Stop the fleet: queued and in-flight requests finish, then the
    /// composer threads join.
    pub fn shutdown(self) {
        self.fleet.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Scheme;
    use crate::metrics::QueryMetrics;
    use crate::scheduler::{JobResult, Priority};

    // Router startup requires artifacts + engine; covered by
    // rust/tests/server_integration.rs. Here: pure serialization.
    #[test]
    fn job_result_serializes_with_serving_telemetry() {
        let mut m = QueryMetrics::default();
        m.answer_correct = true;
        m.thinking_tokens = 99;
        let r = JobResult {
            metrics: m,
            scheme: Scheme::SpecReason,
            priority: Priority::High,
            queue_wait_s: 0.25,
            ttfs_s: 0.5,
            e2e_s: 1.5,
            preemptions: 1,
            prefix_tokens_reused: 64,
            retries: 2,
            degraded: true,
            trace_id: Some(41),
        };
        let j = job_result_to_json(&r);
        assert_eq!(j.get("scheme").as_str(), Some("spec-reason"));
        assert_eq!(j.get("thinking_tokens").as_usize(), Some(99));
        assert_eq!(j.get("priority").as_str(), Some("high"));
        assert_eq!(j.get("preemptions").as_usize(), Some(1));
        assert_eq!(j.get("prefix_tokens_reused").as_usize(), Some(64));
        assert_eq!(j.get("retries").as_usize(), Some(2));
        assert_eq!(j.get("degraded").as_bool(), Some(true));
        assert_eq!(j.get("trace_id").as_usize(), Some(41));
        assert!((j.get("queue_wait_s").as_f64().unwrap() - 0.25).abs() < 1e-12);
        // Without tracing the key is absent entirely (byte-compatible
        // with the pre-observability wire format).
        let r = JobResult { trace_id: None, ..r };
        assert!(job_result_to_json(&r).get("trace_id").is_null());
    }
}
