//! Request router: bounded admission queue in front of a single engine
//! worker.
//!
//! The paper's serving setup executes the two colocated models
//! sequentially ("the small and base models take turns", §4.1), so one
//! worker owns the engine and drains a FIFO queue; connection handlers
//! only parse/serialize.  The queue bound provides backpressure: beyond
//! `max_queue` outstanding requests, new queries are rejected with an
//! `overloaded` error rather than growing latency unboundedly.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::DeployConfig;
use crate::coordinator::{run_query, AcceptancePolicy, Combo, RealBackend, SpecConfig};
use crate::engine::Engine;
use crate::semantics::{Oracle, TraceGenerator};
use crate::server::protocol::{metrics_to_json, QueryRequest};
use crate::util::json::Json;

/// A unit of routed work.
pub struct RoutedQuery {
    pub req: QueryRequest,
    pub reply: mpsc::Sender<Result<Json>>,
}

/// Router statistics (served over the `stats` op).
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    pub admitted: u64,
    pub rejected_overload: u64,
    pub completed: u64,
    pub failed: u64,
    pub queue_depth: usize,
}

pub struct Router {
    tx: Option<mpsc::SyncSender<RoutedQuery>>,
    stats: Arc<Mutex<RouterStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Spawn the engine worker. The engine is created *inside* the worker
    /// thread (it owns the PJRT client for its lifetime).
    pub fn start(cfg: DeployConfig) -> Result<Router> {
        let (tx, rx) = mpsc::sync_channel::<RoutedQuery>(cfg.max_queue);
        let stats = Arc::new(Mutex::new(RouterStats::default()));
        let wstats = Arc::clone(&stats);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("specreason-engine".into())
            .spawn(move || {
                let engine = match Engine::new(&cfg.engine_config()) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let oracle = Oracle::default();
                let combo = Combo::new(&cfg.base_model, &cfg.small_model);
                while let Ok(job) = rx.recv() {
                    {
                        let mut s = wstats.lock().unwrap();
                        s.queue_depth = s.queue_depth.saturating_sub(1);
                    }
                    let result = serve_one(&engine, &oracle, &combo, &cfg, &job.req);
                    {
                        let mut s = wstats.lock().unwrap();
                        match &result {
                            Ok(_) => s.completed += 1,
                            Err(_) => s.failed += 1,
                        }
                    }
                    let _ = job.reply.send(result);
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine worker died during startup"))??;
        Ok(Router { tx: Some(tx), stats, worker: Some(worker) })
    }

    /// Try to admit a query; `Err` means backpressure.
    pub fn submit(&self, req: QueryRequest) -> Result<mpsc::Receiver<Result<Json>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let routed = RoutedQuery { req, reply: reply_tx };
        match self.tx.as_ref().expect("router shut down").try_send(routed) {
            Ok(()) => {
                let mut s = self.stats.lock().unwrap();
                s.admitted += 1;
                s.queue_depth += 1;
                Ok(reply_rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.stats.lock().unwrap().rejected_overload += 1;
                anyhow::bail!("overloaded: admission queue full")
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                anyhow::bail!("engine worker is gone")
            }
        }
    }

    pub fn stats(&self) -> RouterStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn stats_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            ("admitted", Json::num(s.admitted as f64)),
            ("rejected_overload", Json::num(s.rejected_overload as f64)),
            ("completed", Json::num(s.completed as f64)),
            ("failed", Json::num(s.failed as f64)),
            ("queue_depth", Json::num(s.queue_depth as f64)),
        ])
    }

    /// Stop the worker: close the queue (in-flight request finishes) and
    /// join.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        drop(self.tx.take()); // closes the channel; worker drains and exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Execute one routed query on the engine.
fn serve_one(
    engine: &Engine,
    oracle: &Oracle,
    combo: &Combo,
    cfg: &DeployConfig,
    req: &QueryRequest,
) -> Result<Json> {
    let mut spec = cfg.spec_config();
    if let Some(s) = req.scheme {
        spec.scheme = s;
    }
    if let Some(t) = req.threshold {
        spec.policy = AcceptancePolicy::Static { threshold: t };
    }
    if let Some(n) = req.first_n_base {
        spec.first_n_base = n;
    }
    if let Some(b) = req.budget {
        spec.token_budget = b;
    }
    validate_budget(engine, combo, &spec)?;
    let seed = req.seed.unwrap_or(0x5EED);
    let gen = TraceGenerator::new(req.dataset, seed);
    let q = gen.query(req.query_index);
    let mut backend = RealBackend::new(engine, &combo.small, &combo.base);
    let out = run_query(oracle, &q, combo, &spec, &mut backend, req.sample)?;
    backend.release()?;
    Ok(metrics_to_json(&out.metrics, spec.scheme))
}

/// Reject budgets that cannot fit the context window before any compute.
fn validate_budget(engine: &Engine, combo: &Combo, spec: &SpecConfig) -> Result<()> {
    let base = engine.model(&combo.base)?;
    let max_prompt = 160; // generator bound (see DatasetProfile::prompt_len)
    let need = max_prompt + spec.token_budget + spec.verify_template_len + spec.answer_tokens;
    anyhow::ensure!(
        need <= base.arch.max_seq,
        "token_budget {} does not fit the context window ({} needed > {})",
        spec.token_budget, need, base.arch.max_seq
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Router startup requires artifacts + engine; covered by
    // rust/tests/server_integration.rs. Here: pure stats plumbing.
    #[test]
    fn stats_json_shape() {
        let s = RouterStats { admitted: 3, rejected_overload: 1, completed: 2, failed: 0, queue_depth: 1 };
        let j = Json::obj(vec![
            ("admitted", Json::num(s.admitted as f64)),
            ("queue_depth", Json::num(s.queue_depth as f64)),
        ]);
        assert_eq!(j.get("admitted").as_usize(), Some(3));
    }
}
