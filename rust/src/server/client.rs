//! Typed v2 streaming client: connect, submit queries, iterate their
//! event streams, cancel mid-flight.
//!
//! One TCP connection multiplexes any number of concurrently streaming
//! queries plus one-shot control ops (`stats`, `cancel`, `shutdown`,
//! `ping`).  Control acks can interleave with event frames on the wire,
//! so the client buffers event frames encountered while waiting for an
//! ack and replays them from [`StreamClient::next_event`].
//!
//! The v1 one-shot [`Client`](crate::server::Client) stays untouched for
//! pre-v2 deployments; this client speaks only v2.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A parsed v2 event frame.
#[derive(Debug, Clone)]
pub enum WireEvent {
    Queued,
    Admitted,
    Step {
        kind: String,
        step: usize,
        tokens: usize,
        score: Option<u8>,
        effective_threshold: Option<u8>,
    },
    Preempted,
    /// A transient failure was rolled back and the query re-queued for
    /// replay attempt `attempt` after `backoff_ms` of backoff.
    Retried { attempt: u32, backoff_ms: u64 },
    /// Admitted in degraded (base-only) mode under server pressure.
    Degraded,
    /// Terminal: the completed result object.
    Result(Json),
    /// Terminal: structured failure.
    Error { code: String, message: String },
    /// Terminal: the query was cancelled.
    Cancelled,
}

impl WireEvent {
    pub fn is_terminal(&self) -> bool {
        matches!(self, WireEvent::Result(_) | WireEvent::Error { .. } | WireEvent::Cancelled)
    }

    /// Parse an event frame (a frame carrying an `"event"` field).
    pub fn parse(j: &Json) -> Result<WireEvent> {
        Ok(match j.req_str("event")? {
            "queued" => WireEvent::Queued,
            "admitted" => WireEvent::Admitted,
            "preempted" => WireEvent::Preempted,
            "retried" => WireEvent::Retried {
                attempt: j.req_usize("attempt")? as u32,
                backoff_ms: j.req_usize("backoff_ms")? as u64,
            },
            "degraded" => WireEvent::Degraded,
            "step" => WireEvent::Step {
                kind: j.req_str("kind")?.to_string(),
                step: j.req_usize("step")?,
                tokens: j.req_usize("tokens")?,
                score: j.get("score").as_usize().map(|s| s as u8),
                effective_threshold: j
                    .get("effective_threshold")
                    .as_usize()
                    .map(|t| t as u8),
            },
            "result" => WireEvent::Result(j.get("result").clone()),
            "error" => WireEvent::Error {
                code: j.get("code").as_str().unwrap_or("engine_failure").to_string(),
                message: j.get("error").as_str().unwrap_or("").to_string(),
            },
            "cancelled" => WireEvent::Cancelled,
            other => anyhow::bail!("unknown event kind '{other}'"),
        })
    }
}

/// Blocking v2 streaming client.
pub struct StreamClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
    /// Event frames read while waiting for a control ack, replayed by
    /// [`next_event`](Self::next_event) in arrival order.
    pending: VecDeque<(i64, WireEvent)>,
}

impl StreamClient {
    pub fn connect(addr: &str) -> Result<StreamClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(StreamClient {
            reader: BufReader::new(stream),
            writer,
            next_id: 1,
            pending: VecDeque::new(),
        })
    }

    /// Assign an id, stamp `"v": 2`, and write one request line.
    fn send(&mut self, mut body: Json) -> Result<i64> {
        let id = self.next_id;
        self.next_id += 1;
        body.set("id", Json::num(id as f64));
        body.set("v", Json::num(2.0));
        self.writer.write_all(body.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(id)
    }

    fn read_frame(&mut self) -> Result<Json> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.reader.read_line(&mut line)?;
            anyhow::ensure!(n > 0, "server closed the connection");
            if !line.trim().is_empty() {
                break;
            }
        }
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad frame: {e}"))
    }

    /// Submit a v2 query.  `body` carries the query fields (`dataset`,
    /// `scheme`, `budget`, `deadline_ms`, ...); `op`/`id`/`v` are set
    /// here.  Returns the stream id to match against
    /// [`next_event`](Self::next_event).
    pub fn submit(&mut self, mut body: Json) -> Result<i64> {
        body.set("op", Json::str("query"));
        self.send(body)
    }

    /// Block for the next event frame from any stream on this
    /// connection: `(stream id, event)`.
    pub fn next_event(&mut self) -> Result<(i64, WireEvent)> {
        if let Some(ev) = self.pending.pop_front() {
            return Ok(ev);
        }
        let j = self.read_frame()?;
        anyhow::ensure!(
            !j.get("event").is_null(),
            "unexpected control response on the event stream (id {})",
            j.get("id").as_i64().unwrap_or(0)
        );
        let id = j.get("id").as_i64().unwrap_or(0);
        Ok((id, WireEvent::parse(&j)?))
    }

    /// Drain `id`'s stream to its terminal event and return it,
    /// discarding that stream's intermediate events; other streams'
    /// events stay queued for their own consumers.
    pub fn wait_terminal(&mut self, id: i64) -> Result<WireEvent> {
        let mut foreign = VecDeque::new();
        let terminal = loop {
            let (eid, ev) = self.next_event()?;
            if eid != id {
                foreign.push_back((eid, ev));
                continue;
            }
            if ev.is_terminal() {
                break ev;
            }
        };
        // Preserve other streams' frames for their own consumers.
        for item in foreign.into_iter().rev() {
            self.pending.push_front(item);
        }
        Ok(terminal)
    }

    /// One-shot control op: write the request, read (and return) its
    /// ack, buffering any event frames that interleave.
    pub fn call(&mut self, body: Json) -> Result<Json> {
        let id = self.send(body)?;
        loop {
            let j = self.read_frame()?;
            if !j.get("event").is_null() {
                let eid = j.get("id").as_i64().unwrap_or(0);
                // A rejected control op answers with an error *frame*
                // addressed to our id (ids are never shared between
                // control ops and query streams on this client) — that
                // is the ack; buffering it would wait forever.
                if eid == id && j.get("event").as_str() == Some("error") {
                    anyhow::bail!(
                        "server error ({}): {}",
                        j.get("code").as_str().unwrap_or("unknown"),
                        j.get("error").as_str().unwrap_or("unknown")
                    );
                }
                self.pending.push_back((eid, WireEvent::parse(&j)?));
                continue;
            }
            anyhow::ensure!(
                j.get("id").as_i64() == Some(id),
                "control ack for unexpected id {:?} (awaiting {id})",
                j.get("id").as_i64()
            );
            if j.get("ok").as_bool() != Some(true) {
                anyhow::bail!(
                    "server error: {}",
                    j.get("error").as_str().unwrap_or("unknown")
                );
            }
            return Ok(j.get("result").clone());
        }
    }

    /// Cancel an in-flight stream by id.  Returns whether the server
    /// found it in flight and *requested* cancellation; the stream's
    /// terminal frame is `cancelled` unless the job wins the race by
    /// completing in the scheduler tick already in progress (then it is
    /// `result`).
    pub fn cancel(&mut self, target: i64) -> Result<bool> {
        let r = self.call(Json::obj(vec![
            ("op", Json::str("cancel")),
            ("target", Json::num(target as f64)),
        ]))?;
        Ok(r.get("cancelled").as_bool().unwrap_or(false))
    }

    pub fn ping(&mut self) -> Result<()> {
        let r = self.call(Json::obj(vec![("op", Json::str("ping"))]))?;
        anyhow::ensure!(r.as_str() == Some("pong"), "unexpected ping reply");
        Ok(())
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.call(Json::obj(vec![("op", Json::str("stats"))]))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let r = self.call(Json::obj(vec![("op", Json::str("shutdown"))]))?;
        anyhow::ensure!(r.as_str() == Some("bye"), "unexpected shutdown reply");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_events_parse_from_frames() {
        use crate::coordinator::{StepEvent, StepKind};
        use crate::scheduler::JobEvent;
        use crate::server::protocol::event_frame;

        let frame = event_frame(
            5,
            &JobEvent::Step(StepEvent {
                step: 2,
                kind: StepKind::Fallback,
                score: Some(4),
                effective_threshold: Some(7),
                tokens: 12,
            }),
        );
        let j = Json::parse(&frame).unwrap();
        match WireEvent::parse(&j).unwrap() {
            WireEvent::Step { kind, step, tokens, score, effective_threshold } => {
                assert_eq!(kind, "fallback");
                assert_eq!(step, 2);
                assert_eq!(tokens, 12);
                assert_eq!(score, Some(4));
                assert_eq!(effective_threshold, Some(7));
            }
            other => panic!("wrong event: {other:?}"),
        }
        let j = Json::parse(&event_frame(5, &JobEvent::Cancelled)).unwrap();
        assert!(WireEvent::parse(&j).unwrap().is_terminal());
        let j = Json::parse(&event_frame(5, &JobEvent::Queued)).unwrap();
        assert!(!WireEvent::parse(&j).unwrap().is_terminal());
        let retried = JobEvent::Retried { attempt: 3, backoff_ms: 20 };
        let j = Json::parse(&event_frame(5, &retried)).unwrap();
        match WireEvent::parse(&j).unwrap() {
            WireEvent::Retried { attempt, backoff_ms } => {
                assert_eq!((attempt, backoff_ms), (3, 20));
            }
            other => panic!("wrong event: {other:?}"),
        }
        let j = Json::parse(&event_frame(5, &JobEvent::Degraded)).unwrap();
        assert!(!WireEvent::parse(&j).unwrap().is_terminal());
    }
}
