//! Wire protocol: newline-delimited JSON over TCP, in two versions.
//!
//! **v1 (default)** — one-shot request/response, unchanged:
//! ```json
//! {"id": 1, "op": "query", "dataset": "aime", "query_index": 3,
//!  "scheme": "spec-reason", "threshold": 7, "first_n_base": 0,
//!  "budget": 704, "sample": 0, "priority": "high"}
//! {"id": 2, "op": "stats"}
//! {"id": 3, "op": "ping"}
//! {"id": 4, "op": "shutdown"}
//! ```
//! Responses: `{"id": 1, "ok": true, "result": {...}}` or
//! `{"id": 1, "ok": false, "error": "..."}`.
//!
//! **v2 (streaming sessions)** — requests carry `"v": 2` and a
//! *required, connection-unique numeric* `"id"`.  A v2 `query` answers
//! with a stream of NDJSON event frames ending in exactly one terminal
//! frame:
//! ```json
//! {"id": 7, "v": 2, "event": "queued"}
//! {"id": 7, "v": 2, "event": "admitted"}
//! {"id": 7, "v": 2, "event": "step", "kind": "speculated", "step": 0,
//!  "tokens": 18, "effective_threshold": 7}
//! {"id": 7, "v": 2, "event": "step", "kind": "accepted", "step": 0,
//!  "score": 8, "effective_threshold": 7, "tokens": 18}
//! {"id": 7, "v": 2, "event": "preempted"}
//! {"id": 7, "v": 2, "event": "retried", "attempt": 1, "backoff_ms": 5}
//! {"id": 7, "v": 2, "event": "degraded"}
//! {"id": 7, "v": 2, "event": "result", "ok": true, "result": {...}}
//! ```
//! `retried` (a transient failure was rolled back and the job re-queued
//! for replay) and `degraded` (admitted base-only under pressure) are
//! non-terminal lifecycle frames, like `preempted`.
//! Terminal frames are `result`, `error` (with a structured `"code"`:
//! `bad_request | overloaded | cancelled | deadline_exceeded |
//! engine_failure | shutdown`) or `cancelled`.  v2 queries may carry
//! `"deadline_ms"` (enforced end-to-end deadline) and can be aborted
//! mid-flight by `{"id": 9, "v": 2, "op": "cancel", "target": 7}` —
//! cancellation is scoped to the connection that submitted the target,
//! and the ack's `{"cancelled": true}` means *requested*: a job that
//! completes in the scheduler tick already in progress still terminates
//! with `result`.  v2 ids must be integers within ±(2^53 − 1) — the
//! JSON number range where they round-trip exactly.

use anyhow::{Context, Result};

use crate::coordinator::Scheme;
use crate::metrics::QueryMetrics;
use crate::scheduler::{code_of, ErrorCode, JobEvent, JobResult, Priority};
use crate::semantics::Dataset;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub enum Op {
    Ping,
    Stats,
    Shutdown,
    Query(QueryRequest),
    /// Abort an in-flight v2 query (by its request id) on this
    /// connection.
    Cancel { target: i64 },
    /// Observability registry dump: named counters/gauges/histograms
    /// (with p50/p95/p99), flight-recorder rings + retained dumps, and
    /// trace counts.
    Metrics,
    /// One traced request timeline: the given trace id, or the most
    /// recently finished when `target` is omitted.  `null` result when
    /// tracing is off or nothing matches.
    Trace { target: Option<u64> },
}

#[derive(Debug, Clone)]
pub struct QueryRequest {
    pub dataset: Dataset,
    pub query_index: usize,
    pub sample: usize,
    pub scheme: Option<Scheme>,
    pub threshold: Option<u8>,
    pub first_n_base: Option<usize>,
    pub budget: Option<usize>,
    /// Workload seed (defaults to the server's).
    pub seed: Option<u64>,
    /// Scheduling class (defaults to normal).
    pub priority: Option<Priority>,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: i64,
    /// Protocol version: 1 (one-shot, default) or 2 (streaming session).
    pub v: u8,
    /// v2 only: enforced end-to-end deadline for `query` ops.
    pub deadline_ms: Option<u64>,
    pub op: Op,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).context("request is not valid JSON")?;
        let v = match j.get("v") {
            Json::Null => 1u8,
            val => match val.as_usize() {
                Some(1) => 1,
                Some(2) => 2,
                _ => anyhow::bail!("unsupported protocol version (expected 1 or 2)"),
            },
        };
        // v1 keeps the lenient default (missing/non-numeric id -> 0);
        // v2 sessions are addressable (cancel-by-id), so an ambiguous id
        // is a bad_request.
        let id = match j.get("id").as_i64() {
            Some(i) => i,
            None if v >= 2 => {
                anyhow::bail!("v2 requests require a numeric 'id' (used for cancel/streaming)")
            }
            None => 0,
        };
        // Ids are load-bearing on v2 (event matching, cancel targets) and
        // ride JSON numbers (f64): outside ±(2^53 - 1) they no longer
        // round-trip exactly, so frames could address the wrong stream.
        // unsigned_abs: huge floats saturate `as i64` to i64::MIN, whose
        // signed abs() overflows.
        if v >= 2 {
            anyhow::ensure!(
                id.unsigned_abs() < (1u64 << 53),
                "v2 'id' must be within +/-(2^53 - 1) (JSON number precision)"
            );
        }
        // v2-only field; on v1 it stays an ignored unknown field, exactly
        // as pre-versioning servers treated it.
        let deadline_ms = match j.get("deadline_ms") {
            _ if v < 2 => None,
            Json::Null => None,
            val => match val.as_usize() {
                Some(ms) if ms > 0 => Some(ms as u64),
                _ => anyhow::bail!("'deadline_ms' must be a positive integer"),
            },
        };
        let op = match j.req_str("op")? {
            "ping" => Op::Ping,
            "stats" => Op::Stats,
            "shutdown" => Op::Shutdown,
            "metrics" => Op::Metrics,
            "trace" => {
                let target = match j.get("target") {
                    Json::Null => None,
                    val => match val.as_usize() {
                        Some(t) => Some(t as u64),
                        None => anyhow::bail!(
                            "'trace' target must be a non-negative integer trace id"
                        ),
                    },
                };
                Op::Trace { target }
            }
            "cancel" => {
                let target = j
                    .get("target")
                    .as_i64()
                    .ok_or_else(|| anyhow::anyhow!("'cancel' requires a numeric 'target' id"))?;
                Op::Cancel { target }
            }
            "query" => {
                let dataset = Dataset::parse(j.req_str("dataset")?)?;
                let scheme = match j.get("scheme").as_str() {
                    Some(s) => Some(Scheme::parse(s)?),
                    None => None,
                };
                let threshold = match j.get("threshold").as_usize() {
                    Some(t) => {
                        anyhow::ensure!(t <= 9, "threshold must be 0..=9");
                        Some(t as u8)
                    }
                    None => None,
                };
                let priority = match j.get("priority").as_str() {
                    Some(p) => Some(Priority::parse(p)?),
                    None => None,
                };
                Op::Query(QueryRequest {
                    dataset,
                    query_index: j.get("query_index").as_usize().unwrap_or(0),
                    sample: j.get("sample").as_usize().unwrap_or(0),
                    scheme,
                    threshold,
                    first_n_base: j.get("first_n_base").as_usize(),
                    budget: j.get("budget").as_usize(),
                    seed: j.get("seed").as_usize().map(|s| s as u64),
                    priority,
                })
            }
            other => anyhow::bail!("unknown op '{other}'"),
        };
        Ok(Request { id, v, deadline_ms, op })
    }

    /// Best-effort `(id, v)` extraction from a raw request line, for
    /// addressing the error reply to a request that failed to parse.
    /// Any numeric version other than 1 reports as 2 so the error goes
    /// out as a frame addressed to the request's id (a forward-version
    /// client correlates by id); unparseable input reports as v1 id 0 —
    /// exactly the old behavior.
    pub fn peek_meta(line: &str) -> (i64, u8) {
        match Json::parse(line) {
            Ok(j) => {
                let v = match j.get("v").as_usize() {
                    None | Some(1) => 1,
                    Some(_) => 2,
                };
                (j.get("id").as_i64().unwrap_or(0), v)
            }
            Err(_) => (0, 1),
        }
    }
}

/// Build an error response line.
pub fn error_response(id: i64, err: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(err)),
    ])
    .to_string()
}

/// Build a success response line.
pub fn ok_response(id: i64, result: Json) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("result", result),
    ])
    .to_string()
}

/// Serialize a completed request for the wire: the per-query metrics plus
/// serving-side telemetry (queue wait, time-to-first-step, preemptions).
pub fn job_result_to_json(r: &JobResult) -> Json {
    let mut j = metrics_to_json(&r.metrics, r.scheme);
    j.set("priority", Json::str(r.priority.name()));
    j.set("queue_wait_s", Json::num(r.queue_wait_s));
    j.set("ttfs_s", Json::num(r.ttfs_s));
    j.set("e2e_s", Json::num(r.e2e_s));
    j.set("preemptions", Json::num(r.preemptions as f64));
    j.set("prefix_tokens_reused", Json::num(r.prefix_tokens_reused as f64));
    j.set("retries", Json::num(r.retries as f64));
    j.set("degraded", Json::Bool(r.degraded));
    if let Some(id) = r.trace_id {
        j.set("trace_id", Json::num(id as f64));
    }
    j
}

/// Build one v2 NDJSON event frame for a session's [`JobEvent`].
pub fn event_frame(id: i64, ev: &JobEvent) -> String {
    let mut j = Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("v", Json::num(2.0)),
    ]);
    match ev {
        JobEvent::Queued => j.set("event", Json::str("queued")),
        JobEvent::Admitted => j.set("event", Json::str("admitted")),
        JobEvent::Preempted => j.set("event", Json::str("preempted")),
        JobEvent::Retried { attempt, backoff_ms } => {
            j.set("event", Json::str("retried"));
            j.set("attempt", Json::num(*attempt as f64));
            j.set("backoff_ms", Json::num(*backoff_ms as f64));
        }
        JobEvent::Degraded => j.set("event", Json::str("degraded")),
        JobEvent::Step(s) => {
            j.set("event", Json::str("step"));
            j.set("kind", Json::str(s.kind.name()));
            j.set("step", Json::num(s.step as f64));
            j.set("tokens", Json::num(s.tokens as f64));
            if let Some(score) = s.score {
                j.set("score", Json::num(score as f64));
            }
            if let Some(thr) = s.effective_threshold {
                j.set("effective_threshold", Json::num(thr as f64));
            }
        }
        JobEvent::Result(r) => {
            j.set("event", Json::str("result"));
            j.set("ok", Json::Bool(true));
            j.set("result", job_result_to_json(r));
        }
        JobEvent::Error(e) => {
            j.set("event", Json::str("error"));
            j.set("ok", Json::Bool(false));
            j.set("code", Json::str(code_of(e).name()));
            j.set("error", Json::str(format!("{e:#}")));
        }
        JobEvent::Cancelled => {
            j.set("event", Json::str("cancelled"));
            j.set("ok", Json::Bool(false));
            j.set("code", Json::str(ErrorCode::Cancelled.name()));
        }
    }
    j.to_string()
}

/// Build a terminal v2 error frame outside a live job stream (parse
/// failures, submit rejections, duplicate ids).
pub fn error_frame(id: i64, code: ErrorCode, err: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("v", Json::num(2.0)),
        ("event", Json::str("error")),
        ("ok", Json::Bool(false)),
        ("code", Json::str(code.name())),
        ("error", Json::str(err)),
    ])
    .to_string()
}

/// Serialize query metrics for the wire.
pub fn metrics_to_json(m: &QueryMetrics, scheme: Scheme) -> Json {
    let mut phases = Json::Obj(Default::default());
    for (k, v) in &m.phase_wall {
        phases.set(k, Json::num(*v));
    }
    Json::obj(vec![
        ("scheme", Json::str(scheme.name())),
        ("correct", Json::Bool(m.answer_correct)),
        ("wall_secs", Json::num(m.wall_secs)),
        ("gpu_secs", Json::num(m.gpu_secs)),
        ("thinking_tokens", Json::num(m.thinking_tokens as f64)),
        ("steps_total", Json::num(m.steps_total as f64)),
        ("steps_speculated", Json::num(m.steps_speculated as f64)),
        ("steps_accepted", Json::num(m.steps_accepted as f64)),
        ("acceptance_rate", Json::num(m.acceptance_rate())),
        ("offload_ratio", Json::num(m.offload_ratio())),
        ("lookahead_drafted_tokens", Json::num(m.lookahead_drafted_tokens as f64)),
        ("lookahead_discarded_tokens", Json::num(m.lookahead_discarded_tokens as f64)),
        ("lookahead_overlap_gpu_s", Json::num(m.lookahead_overlap_gpu)),
        ("phase_wall", phases),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_query_request() {
        let r = Request::parse(
            r#"{"id": 7, "op": "query", "dataset": "math500", "query_index": 2,
                "scheme": "spec-reason", "threshold": 5, "budget": 256}"#,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        match r.op {
            Op::Query(q) => {
                assert_eq!(q.dataset, Dataset::Math500);
                assert_eq!(q.query_index, 2);
                assert_eq!(q.scheme, Some(Scheme::SpecReason));
                assert_eq!(q.threshold, Some(5));
                assert_eq!(q.budget, Some(256));
                assert_eq!(q.first_n_base, None);
                assert_eq!(q.priority, None);
            }
            _ => panic!("wrong op"),
        }
    }

    #[test]
    fn parses_priority_class() {
        let r = Request::parse(
            r#"{"op": "query", "dataset": "aime", "priority": "high"}"#,
        )
        .unwrap();
        match r.op {
            Op::Query(q) => assert_eq!(q.priority, Some(Priority::High)),
            _ => panic!("wrong op"),
        }
        assert!(Request::parse(
            r#"{"op": "query", "dataset": "aime", "priority": "urgent"}"#
        )
        .is_err());
    }

    #[test]
    fn parses_control_ops() {
        assert!(matches!(Request::parse(r#"{"op":"ping"}"#).unwrap().op, Op::Ping));
        assert!(matches!(Request::parse(r#"{"op":"stats"}"#).unwrap().op, Op::Stats));
        assert!(matches!(
            Request::parse(r#"{"op":"shutdown"}"#).unwrap().op,
            Op::Shutdown
        ));
    }

    #[test]
    fn parses_observability_ops() {
        assert!(matches!(
            Request::parse(r#"{"op":"metrics"}"#).unwrap().op,
            Op::Metrics
        ));
        assert!(matches!(
            Request::parse(r#"{"op":"trace"}"#).unwrap().op,
            Op::Trace { target: None }
        ));
        assert!(matches!(
            Request::parse(r#"{"op":"trace","target":7}"#).unwrap().op,
            Op::Trace { target: Some(7) }
        ));
        assert!(Request::parse(r#"{"op":"trace","target":"latest"}"#).is_err());
        assert!(Request::parse(r#"{"op":"trace","target":-3}"#).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Request::parse("nope").is_err());
        assert!(Request::parse(r#"{"op":"warp"}"#).is_err());
        assert!(Request::parse(r#"{"op":"query"}"#).is_err()); // no dataset
        assert!(Request::parse(r#"{"op":"query","dataset":"aime","threshold":11}"#).is_err());
    }

    #[test]
    fn v1_keeps_lenient_id_default() {
        // v1 compat: missing or non-numeric ids coerce to 0, exactly as
        // before the v2 redesign.
        let r = Request::parse(r#"{"op":"ping"}"#).unwrap();
        assert_eq!((r.id, r.v), (0, 1));
        let r = Request::parse(r#"{"id":"seven","op":"ping"}"#).unwrap();
        assert_eq!((r.id, r.v), (0, 1));
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn v2_requires_numeric_id() {
        let err = Request::parse(r#"{"v":2,"op":"query","dataset":"aime"}"#).unwrap_err();
        assert!(format!("{err:#}").contains("numeric 'id'"));
        let err = Request::parse(r#"{"v":2,"id":"x","op":"ping"}"#).unwrap_err();
        assert!(format!("{err:#}").contains("numeric 'id'"));
        let r = Request::parse(r#"{"v":2,"id":9,"op":"query","dataset":"aime"}"#).unwrap();
        assert_eq!((r.id, r.v), (9, 2));
        // Unknown versions are rejected outright.
        assert!(Request::parse(r#"{"v":3,"id":1,"op":"ping"}"#).is_err());
        assert!(Request::parse(r#"{"v":"two","id":1,"op":"ping"}"#).is_err());
        // Ids outside the exact-f64 integer range cannot address streams
        // reliably — rejected on v2, still lenient on v1.
        let err =
            Request::parse(r#"{"v":2,"id":9007199254740993,"op":"ping"}"#).unwrap_err();
        assert!(format!("{err:#}").contains("2^53"));
        let max_ok = (1i64 << 53) - 1;
        let line = format!(r#"{{"v":2,"id":{max_ok},"op":"ping"}}"#);
        assert_eq!(Request::parse(&line).unwrap().id, max_ok);
        // Huge floats saturate `as i64` to i64::MIN — must reject, not
        // overflow (signed abs of i64::MIN panics in debug builds).
        assert!(Request::parse(r#"{"v":2,"id":-1e300,"op":"ping"}"#).is_err());
        assert!(Request::parse(r#"{"id":9007199254740993,"op":"ping"}"#).is_ok());
    }

    #[test]
    fn parses_cancel_and_deadline() {
        let r = Request::parse(r#"{"v":2,"id":9,"op":"cancel","target":7}"#).unwrap();
        match r.op {
            Op::Cancel { target } => assert_eq!(target, 7),
            _ => panic!("wrong op"),
        }
        assert!(Request::parse(r#"{"v":2,"id":9,"op":"cancel"}"#).is_err());
        let r = Request::parse(
            r#"{"v":2,"id":4,"op":"query","dataset":"aime","deadline_ms":1500}"#,
        )
        .unwrap();
        assert_eq!(r.deadline_ms, Some(1500));
        assert!(Request::parse(
            r#"{"v":2,"id":4,"op":"query","dataset":"aime","deadline_ms":0}"#
        )
        .is_err());
        assert!(Request::parse(
            r#"{"v":2,"id":4,"op":"query","dataset":"aime","deadline_ms":"soon"}"#
        )
        .is_err());
        // On v1, deadline_ms stays an ignored unknown field (even when
        // malformed), exactly as pre-versioning servers treated it.
        let r =
            Request::parse(r#"{"op":"query","dataset":"aime","deadline_ms":1500}"#).unwrap();
        assert_eq!(r.deadline_ms, None);
        let r =
            Request::parse(r#"{"op":"query","dataset":"aime","deadline_ms":0}"#).unwrap();
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn peek_meta_recovers_id_and_version() {
        assert_eq!(Request::peek_meta(r#"{"v":2,"id":5,"op":"warp"}"#), (5, 2));
        // Forward versions answer as frames addressed to the id, not as
        // an anonymous v1 error.
        assert_eq!(Request::peek_meta(r#"{"v":3,"id":5,"op":"ping"}"#), (5, 2));
        assert_eq!(Request::peek_meta(r#"{"op":"warp"}"#), (0, 1));
        assert_eq!(Request::peek_meta("garbage"), (0, 1));
    }

    #[test]
    fn event_frames_are_valid_json() {
        use crate::coordinator::{StepEvent, StepKind};
        use crate::scheduler::{coded, ErrorCode, JobEvent};

        let step = JobEvent::Step(StepEvent {
            step: 3,
            kind: StepKind::Accepted,
            score: Some(8),
            effective_threshold: Some(7),
            tokens: 21,
        });
        let j = Json::parse(&event_frame(7, &step)).unwrap();
        assert_eq!(j.get("id").as_i64(), Some(7));
        assert_eq!(j.get("v").as_usize(), Some(2));
        assert_eq!(j.get("event").as_str(), Some("step"));
        assert_eq!(j.get("kind").as_str(), Some("accepted"));
        assert_eq!(j.get("score").as_usize(), Some(8));
        assert_eq!(j.get("effective_threshold").as_usize(), Some(7));
        assert_eq!(j.get("tokens").as_usize(), Some(21));

        for (ev, name) in [
            (JobEvent::Queued, "queued"),
            (JobEvent::Admitted, "admitted"),
            (JobEvent::Preempted, "preempted"),
            (JobEvent::Degraded, "degraded"),
        ] {
            let j = Json::parse(&event_frame(1, &ev)).unwrap();
            assert_eq!(j.get("event").as_str(), Some(name));
            assert!(j.get("ok").is_null(), "{name} is not terminal");
        }

        let retried = JobEvent::Retried { attempt: 2, backoff_ms: 10 };
        let j = Json::parse(&event_frame(5, &retried)).unwrap();
        assert_eq!(j.get("event").as_str(), Some("retried"));
        assert_eq!(j.get("attempt").as_usize(), Some(2));
        assert_eq!(j.get("backoff_ms").as_usize(), Some(10));
        assert!(j.get("ok").is_null(), "retried is not terminal");

        let err = JobEvent::Error(coded(ErrorCode::DeadlineExceeded, "too late"));
        let j = Json::parse(&event_frame(2, &err)).unwrap();
        assert_eq!(j.get("event").as_str(), Some("error"));
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("code").as_str(), Some("deadline_exceeded"));
        assert_eq!(j.get("error").as_str(), Some("too late"));

        let j = Json::parse(&event_frame(3, &JobEvent::Cancelled)).unwrap();
        assert_eq!(j.get("event").as_str(), Some("cancelled"));
        assert_eq!(j.get("code").as_str(), Some("cancelled"));

        let j = Json::parse(&error_frame(4, ErrorCode::BadRequest, "nope")).unwrap();
        assert_eq!(j.get("code").as_str(), Some("bad_request"));
        assert_eq!(j.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn responses_are_valid_json() {
        let e = error_response(3, "boom \"quoted\"");
        let j = Json::parse(&e).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        let o = ok_response(4, Json::num(1.5));
        let j = Json::parse(&o).unwrap();
        assert_eq!(j.get("result").as_f64(), Some(1.5));
    }

    #[test]
    fn metrics_serialize() {
        let mut m = QueryMetrics::default();
        m.answer_correct = true;
        m.thinking_tokens = 321;
        m.steps_total = 9;
        let j = metrics_to_json(&m, Scheme::SpecReason);
        assert_eq!(j.get("correct").as_bool(), Some(true));
        assert_eq!(j.get("thinking_tokens").as_usize(), Some(321));
        assert_eq!(j.get("lookahead_drafted_tokens").as_usize(), Some(0));
        assert_eq!(j.get("lookahead_overlap_gpu_s").as_f64(), Some(0.0));
    }
}
