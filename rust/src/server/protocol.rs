//! Wire protocol: newline-delimited JSON over TCP.
//!
//! Requests:
//! ```json
//! {"id": 1, "op": "query", "dataset": "aime", "query_index": 3,
//!  "scheme": "spec-reason", "threshold": 7, "first_n_base": 0,
//!  "budget": 704, "sample": 0, "priority": "high"}
//! {"id": 2, "op": "stats"}
//! {"id": 3, "op": "ping"}
//! {"id": 4, "op": "shutdown"}
//! ```
//! Responses: `{"id": 1, "ok": true, "result": {...}}` or
//! `{"id": 1, "ok": false, "error": "..."}`.

use anyhow::{Context, Result};

use crate::coordinator::Scheme;
use crate::metrics::QueryMetrics;
use crate::scheduler::Priority;
use crate::semantics::Dataset;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub enum Op {
    Ping,
    Stats,
    Shutdown,
    Query(QueryRequest),
}

#[derive(Debug, Clone)]
pub struct QueryRequest {
    pub dataset: Dataset,
    pub query_index: usize,
    pub sample: usize,
    pub scheme: Option<Scheme>,
    pub threshold: Option<u8>,
    pub first_n_base: Option<usize>,
    pub budget: Option<usize>,
    /// Workload seed (defaults to the server's).
    pub seed: Option<u64>,
    /// Scheduling class (defaults to normal).
    pub priority: Option<Priority>,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: i64,
    pub op: Op,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).context("request is not valid JSON")?;
        let id = j.get("id").as_i64().unwrap_or(0);
        let op = match j.req_str("op")? {
            "ping" => Op::Ping,
            "stats" => Op::Stats,
            "shutdown" => Op::Shutdown,
            "query" => {
                let dataset = Dataset::parse(j.req_str("dataset")?)?;
                let scheme = match j.get("scheme").as_str() {
                    Some(s) => Some(Scheme::parse(s)?),
                    None => None,
                };
                let threshold = match j.get("threshold").as_usize() {
                    Some(t) => {
                        anyhow::ensure!(t <= 9, "threshold must be 0..=9");
                        Some(t as u8)
                    }
                    None => None,
                };
                let priority = match j.get("priority").as_str() {
                    Some(p) => Some(Priority::parse(p)?),
                    None => None,
                };
                Op::Query(QueryRequest {
                    dataset,
                    query_index: j.get("query_index").as_usize().unwrap_or(0),
                    sample: j.get("sample").as_usize().unwrap_or(0),
                    scheme,
                    threshold,
                    first_n_base: j.get("first_n_base").as_usize(),
                    budget: j.get("budget").as_usize(),
                    seed: j.get("seed").as_usize().map(|s| s as u64),
                    priority,
                })
            }
            other => anyhow::bail!("unknown op '{other}'"),
        };
        Ok(Request { id, op })
    }
}

/// Build an error response line.
pub fn error_response(id: i64, err: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(err)),
    ])
    .to_string()
}

/// Build a success response line.
pub fn ok_response(id: i64, result: Json) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("result", result),
    ])
    .to_string()
}

/// Serialize query metrics for the wire.
pub fn metrics_to_json(m: &QueryMetrics, scheme: Scheme) -> Json {
    let mut phases = Json::Obj(Default::default());
    for (k, v) in &m.phase_wall {
        phases.set(k, Json::num(*v));
    }
    Json::obj(vec![
        ("scheme", Json::str(scheme.name())),
        ("correct", Json::Bool(m.answer_correct)),
        ("wall_secs", Json::num(m.wall_secs)),
        ("gpu_secs", Json::num(m.gpu_secs)),
        ("thinking_tokens", Json::num(m.thinking_tokens as f64)),
        ("steps_total", Json::num(m.steps_total as f64)),
        ("steps_speculated", Json::num(m.steps_speculated as f64)),
        ("steps_accepted", Json::num(m.steps_accepted as f64)),
        ("acceptance_rate", Json::num(m.acceptance_rate())),
        ("offload_ratio", Json::num(m.offload_ratio())),
        ("phase_wall", phases),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_query_request() {
        let r = Request::parse(
            r#"{"id": 7, "op": "query", "dataset": "math500", "query_index": 2,
                "scheme": "spec-reason", "threshold": 5, "budget": 256}"#,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        match r.op {
            Op::Query(q) => {
                assert_eq!(q.dataset, Dataset::Math500);
                assert_eq!(q.query_index, 2);
                assert_eq!(q.scheme, Some(Scheme::SpecReason));
                assert_eq!(q.threshold, Some(5));
                assert_eq!(q.budget, Some(256));
                assert_eq!(q.first_n_base, None);
                assert_eq!(q.priority, None);
            }
            _ => panic!("wrong op"),
        }
    }

    #[test]
    fn parses_priority_class() {
        let r = Request::parse(
            r#"{"op": "query", "dataset": "aime", "priority": "high"}"#,
        )
        .unwrap();
        match r.op {
            Op::Query(q) => assert_eq!(q.priority, Some(Priority::High)),
            _ => panic!("wrong op"),
        }
        assert!(Request::parse(
            r#"{"op": "query", "dataset": "aime", "priority": "urgent"}"#
        )
        .is_err());
    }

    #[test]
    fn parses_control_ops() {
        assert!(matches!(Request::parse(r#"{"op":"ping"}"#).unwrap().op, Op::Ping));
        assert!(matches!(Request::parse(r#"{"op":"stats"}"#).unwrap().op, Op::Stats));
        assert!(matches!(
            Request::parse(r#"{"op":"shutdown"}"#).unwrap().op,
            Op::Shutdown
        ));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Request::parse("nope").is_err());
        assert!(Request::parse(r#"{"op":"warp"}"#).is_err());
        assert!(Request::parse(r#"{"op":"query"}"#).is_err()); // no dataset
        assert!(Request::parse(r#"{"op":"query","dataset":"aime","threshold":11}"#).is_err());
    }

    #[test]
    fn responses_are_valid_json() {
        let e = error_response(3, "boom \"quoted\"");
        let j = Json::parse(&e).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        let o = ok_response(4, Json::num(1.5));
        let j = Json::parse(&o).unwrap();
        assert_eq!(j.get("result").as_f64(), Some(1.5));
    }

    #[test]
    fn metrics_serialize() {
        let mut m = QueryMetrics::default();
        m.answer_correct = true;
        m.thinking_tokens = 321;
        m.steps_total = 9;
        let j = metrics_to_json(&m, Scheme::SpecReason);
        assert_eq!(j.get("correct").as_bool(), Some(true));
        assert_eq!(j.get("thinking_tokens").as_usize(), Some(321));
    }
}
