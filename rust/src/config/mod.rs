//! Deployment configuration: JSON config file + programmatic defaults.
//!
//! A deployment names the colocated models (base + speculator), the KV
//! partition sizes, the serving address, and default SpecReason knobs.
//! `specreason serve --config deploy.json` loads one; every field can be
//! overridden on the CLI.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{AcceptancePolicy, Scheme, SpecConfig};
use crate::engine::EngineConfig;
use crate::exec::{ExecConfig, PinPolicy};
use crate::metrics::Testbed;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct DeployConfig {
    pub artifacts_dir: String,
    pub base_model: String,
    pub small_model: String,
    pub addr: String,
    pub kv_block_size: usize,
    pub kv_seqs_per_model: usize,
    /// Share KV blocks across requests with a common prompt prefix
    /// (refcounted copy-on-write blocks + a radix prefix index per
    /// partition).  Off (the default) is bit-identical to the
    /// exclusive-ownership pool.
    pub prefix_cache: bool,
    /// Cached-block budget per partition for the prefix cache; 0 means
    /// "bounded only by the pool" (pressure eviction applies either way).
    pub prefix_cache_blocks: usize,
    pub temperature: f32,
    /// Default workload seed for requests that omit `"seed"` (the
    /// protocol documents per-request seeds as "defaults to the
    /// server's" — this is the server's).
    pub seed: u64,
    /// Default request knobs (overridable per request).
    pub scheme: Scheme,
    pub threshold: u8,
    pub first_n_base: usize,
    pub token_budget: usize,
    pub answer_tokens: usize,
    pub verify_template_len: usize,
    pub draft_k: usize,
    /// Admission queue bound (backpressure beyond this).
    pub max_queue: usize,
    /// Connection-handler threads.
    pub io_threads: usize,
    /// In-flight sequences the scheduler batches per engine step.
    /// `1` reproduces the serial router exactly (bit-identical
    /// deterministic metrics); raise it to trade per-request latency for
    /// server throughput.
    pub max_batch: usize,
    /// Allow the scheduler to evict a lower-priority in-flight sequence
    /// when a higher class would otherwise starve.
    pub preempt: bool,
    /// End-to-end latency SLO in milliseconds (0 disables the counter);
    /// completions slower than this increment `slo_violations`.
    pub slo_ms: u64,
    /// Process-wide executor sizing/placement: `threads` (JSON) or
    /// `--threads` (CLI, env-backed by `SPECREASON_BENCH_THREADS`) and
    /// `pin` (`"floating"|"pinned"`) govern the one worker substrate
    /// that serving (connection handlers + batched engine passes) and
    /// eval sweeps share.
    pub exec: ExecConfig,
}

impl Default for DeployConfig {
    fn default() -> Self {
        let spec = SpecConfig::default();
        DeployConfig {
            artifacts_dir: "artifacts".into(),
            base_model: "qwq-sim".into(),
            small_model: "r1-sim".into(),
            addr: "127.0.0.1:7878".into(),
            kv_block_size: 32,
            kv_seqs_per_model: 8,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            temperature: 0.6,
            seed: 0x5EED,
            scheme: Scheme::SpecReason,
            threshold: 7,
            first_n_base: 0,
            token_budget: spec.token_budget,
            answer_tokens: spec.answer_tokens,
            verify_template_len: spec.verify_template_len,
            draft_k: spec.draft_k,
            max_queue: 64,
            io_threads: 4,
            max_batch: 1,
            preempt: true,
            slo_ms: 0,
            exec: ExecConfig::default(),
        }
    }
}

impl DeployConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<DeployConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<DeployConfig> {
        let j = Json::parse(text).context("parsing deploy config JSON")?;
        let mut c = DeployConfig::default();
        if let Some(v) = j.get("artifacts_dir").as_str() {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("base_model").as_str() {
            c.base_model = v.to_string();
        }
        if let Some(v) = j.get("small_model").as_str() {
            c.small_model = v.to_string();
        }
        if let Some(v) = j.get("addr").as_str() {
            c.addr = v.to_string();
        }
        if let Some(v) = j.get("kv_block_size").as_usize() {
            c.kv_block_size = v;
        }
        if let Some(v) = j.get("kv_seqs_per_model").as_usize() {
            c.kv_seqs_per_model = v;
        }
        if let Some(v) = j.get("prefix_cache").as_bool() {
            c.prefix_cache = v;
        }
        if let Some(v) = j.get("prefix_cache_blocks").as_usize() {
            c.prefix_cache_blocks = v;
        }
        if let Some(v) = j.get("temperature").as_f64() {
            c.temperature = v as f32;
        }
        if let Some(v) = j.get("seed").as_usize() {
            c.seed = v as u64;
        }
        if let Some(v) = j.get("scheme").as_str() {
            c.scheme = Scheme::parse(v)?;
        }
        if let Some(v) = j.get("threshold").as_usize() {
            anyhow::ensure!(v <= 9, "threshold must be 0..=9");
            c.threshold = v as u8;
        }
        if let Some(v) = j.get("first_n_base").as_usize() {
            c.first_n_base = v;
        }
        if let Some(v) = j.get("token_budget").as_usize() {
            c.token_budget = v;
        }
        if let Some(v) = j.get("answer_tokens").as_usize() {
            c.answer_tokens = v;
        }
        if let Some(v) = j.get("verify_template_len").as_usize() {
            c.verify_template_len = v;
        }
        if let Some(v) = j.get("draft_k").as_usize() {
            anyhow::ensure!(v >= 1, "draft_k must be >= 1");
            c.draft_k = v;
        }
        if let Some(v) = j.get("max_queue").as_usize() {
            c.max_queue = v;
        }
        if let Some(v) = j.get("io_threads").as_usize() {
            anyhow::ensure!(v >= 1, "io_threads must be >= 1");
            c.io_threads = v;
        }
        if let Some(v) = j.get("max_batch").as_usize() {
            c.max_batch = v;
        }
        if let Some(v) = j.get("preempt").as_bool() {
            c.preempt = v;
        }
        if let Some(v) = j.get("slo_ms").as_usize() {
            c.slo_ms = v as u64;
        }
        if let Some(v) = j.get("threads").as_usize() {
            c.exec.workers = Some(v);
        }
        if let Some(v) = j.get("pin").as_str() {
            c.exec.pin = PinPolicy::parse(v)?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.token_budget >= 16, "token_budget too small");
        anyhow::ensure!(self.kv_block_size >= 1, "kv_block_size must be >= 1");
        anyhow::ensure!(
            self.base_model != self.small_model,
            "base and small model must differ"
        );
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(
            self.exec.workers != Some(0),
            "threads must be >= 1 (omit it for auto: SPECREASON_BENCH_THREADS or \
             available parallelism)"
        );
        Ok(())
    }

    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            artifacts_dir: self.artifacts_dir.clone(),
            models: vec![self.base_model.clone(), self.small_model.clone()],
            testbed: if crate::semantics::ModelClass::of(&self.base_model)
                == crate::semantics::ModelClass::Large
            {
                Testbed::A100x4
            } else {
                Testbed::A6000x2
            },
            kv_block_size: self.kv_block_size,
            kv_seqs_per_model: self.kv_seqs_per_model,
            prefix_cache: self.prefix_cache,
            prefix_cache_blocks: self.prefix_cache_blocks,
            temperature: self.temperature,
        }
    }

    pub fn spec_config(&self) -> SpecConfig {
        SpecConfig {
            scheme: self.scheme,
            policy: AcceptancePolicy::Static { threshold: self.threshold },
            first_n_base: self.first_n_base,
            token_budget: self.token_budget,
            answer_tokens: self.answer_tokens,
            verify_template_len: self.verify_template_len,
            draft_k: self.draft_k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        DeployConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_overrides() {
        let c = DeployConfig::from_json_str(
            r#"{"base_model": "skywork-sim", "small_model": "zr1-sim",
                "scheme": "spec-reason+decode", "threshold": 5,
                "token_budget": 512, "temperature": 0.8}"#,
        )
        .unwrap();
        assert_eq!(c.base_model, "skywork-sim");
        assert_eq!(c.scheme, Scheme::SpecReasonPlusDecode);
        assert_eq!(c.threshold, 5);
        assert_eq!(c.token_budget, 512);
        assert!((c.temperature - 0.8).abs() < 1e-6);
        // untouched fields keep defaults
        assert_eq!(c.addr, "127.0.0.1:7878");
        assert_eq!(c.max_batch, 1);
        assert!(c.preempt);
        assert_eq!(c.slo_ms, 0);
        assert_eq!(c.seed, 0x5EED);
    }

    #[test]
    fn parses_default_seed() {
        let c = DeployConfig::from_json_str(r#"{"seed": 4242}"#).unwrap();
        assert_eq!(c.seed, 4242);
    }

    #[test]
    fn parses_prefix_cache_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"prefix_cache": true, "prefix_cache_blocks": 128}"#,
        )
        .unwrap();
        assert!(c.prefix_cache);
        assert_eq!(c.prefix_cache_blocks, 128);
        let e = c.engine_config();
        assert!(e.prefix_cache);
        assert_eq!(e.prefix_cache_blocks, 128);
        // Default: off, auto budget — bit-identical serving semantics.
        let d = DeployConfig::default();
        assert!(!d.prefix_cache);
        assert_eq!(d.prefix_cache_blocks, 0);
        assert!(!d.engine_config().prefix_cache);
    }

    #[test]
    fn parses_scheduler_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"max_batch": 8, "preempt": false, "slo_ms": 30000, "max_queue": 128}"#,
        )
        .unwrap();
        assert_eq!(c.max_batch, 8);
        assert!(!c.preempt);
        assert_eq!(c.slo_ms, 30000);
        assert_eq!(c.max_queue, 128);
        assert!(DeployConfig::from_json_str(r#"{"max_batch": 0}"#).is_err());
    }

    #[test]
    fn parses_exec_knobs() {
        let c = DeployConfig::from_json_str(r#"{"threads": 6, "pin": "pinned"}"#).unwrap();
        assert_eq!(c.exec.workers, Some(6));
        assert_eq!(c.exec.pin, PinPolicy::Pinned);
        // Default: auto-sized, floating.
        let d = DeployConfig::default();
        assert_eq!(d.exec.workers, None);
        assert_eq!(d.exec.pin, PinPolicy::Floating);
        // threads=0 is a hard error, not a silent fallback.
        let err = DeployConfig::from_json_str(r#"{"threads": 0}"#).unwrap_err();
        assert!(format!("{err:#}").contains("threads must be >= 1"));
        assert!(DeployConfig::from_json_str(r#"{"pin": "warp"}"#).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(DeployConfig::from_json_str(r#"{"threshold": 12}"#).is_err());
        assert!(DeployConfig::from_json_str(r#"{"scheme": "warp"}"#).is_err());
        assert!(DeployConfig::from_json_str(
            r#"{"base_model": "x", "small_model": "x"}"#
        )
        .is_err());
        assert!(DeployConfig::from_json_str("not json").is_err());
    }

    #[test]
    fn large_base_selects_a100_testbed() {
        let c = DeployConfig::from_json_str(r#"{"base_model": "r1-70b-sim"}"#).unwrap();
        assert_eq!(c.engine_config().testbed, Testbed::A100x4);
    }
}
