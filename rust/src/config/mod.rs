//! Deployment configuration: JSON config file + programmatic defaults.
//!
//! A deployment names the colocated models (base + speculator), the KV
//! partition sizes, the serving address, and default SpecReason knobs.
//! `specreason serve --config deploy.json` loads one; every field can be
//! overridden on the CLI.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{AcceptancePolicy, Scheme, SpecConfig};
use crate::engine::EngineConfig;
use crate::exec::{ExecConfig, PinPolicy};
use crate::faults::FaultPlan;
use crate::metrics::Testbed;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct DeployConfig {
    pub artifacts_dir: String,
    pub base_model: String,
    pub small_model: String,
    pub addr: String,
    pub kv_block_size: usize,
    pub kv_seqs_per_model: usize,
    /// Share KV blocks across requests with a common prompt prefix
    /// (refcounted copy-on-write blocks + a radix prefix index per
    /// partition).  Off (the default) is bit-identical to the
    /// exclusive-ownership pool.
    pub prefix_cache: bool,
    /// Cached-block budget per partition for the prefix cache; 0 means
    /// "bounded only by the pool" (pressure eviction applies either way).
    pub prefix_cache_blocks: usize,
    pub temperature: f32,
    /// Default workload seed for requests that omit `"seed"` (the
    /// protocol documents per-request seeds as "defaults to the
    /// server's" — this is the server's).
    pub seed: u64,
    /// Default request knobs (overridable per request).
    pub scheme: Scheme,
    pub threshold: u8,
    pub first_n_base: usize,
    pub token_budget: usize,
    pub answer_tokens: usize,
    pub verify_template_len: usize,
    pub draft_k: usize,
    /// Lookahead pipelining depth (`serve --lookahead`): while the base
    /// model verifies step N, keep drafting steps N+1..N+k from the
    /// unverified frontier with the small model.  0 (the default) is
    /// bit-identical serial behavior; requires a step-speculating
    /// scheme.  Degrade's base-only mode zeroes it per admission.
    pub lookahead_k: usize,
    /// Admission queue bound (backpressure beyond this).
    pub max_queue: usize,
    /// Connection-handler threads.
    pub io_threads: usize,
    /// In-flight sequences the scheduler batches per engine step.
    /// `1` reproduces the serial router exactly (bit-identical
    /// deterministic metrics); raise it to trade per-request latency for
    /// server throughput.
    pub max_batch: usize,
    /// Allow the scheduler to evict a lower-priority in-flight sequence
    /// when a higher class would otherwise starve.
    pub preempt: bool,
    /// Engine replicas behind the serving endpoint (`serve --replicas`).
    /// `1` (the default) runs the single scheduler directly —
    /// bit-identical to the pre-replica path; `N ≥ 2` spawns N
    /// schedulers (each with its own engine and KV partitions) behind
    /// the prefix-affinity replica router.
    pub replicas: usize,
    /// Prefix-affinity placement: probe every replica's radix prefix
    /// index and place a request on the replica already holding the
    /// longest cached prefix of its prompt, falling back to a
    /// consistent hash over the prompt's leading blocks when nothing is
    /// resident.  Off: hash placement only.  Irrelevant at
    /// `replicas = 1`.
    pub replica_affinity: bool,
    /// Per-replica load (queued + running) past which a placement
    /// spills to the least-loaded replica instead.  0 (the default)
    /// disables spill.
    pub replica_spill_watermark: usize,
    /// End-to-end latency SLO in milliseconds (0 disables the counter);
    /// completions slower than this increment `slo_violations`.
    pub slo_ms: u64,
    /// Process-wide executor sizing/placement: `threads` (JSON) or
    /// `--threads` (CLI, env-backed by `SPECREASON_BENCH_THREADS`) and
    /// `pin` (`"floating"|"pinned"`) govern the one worker substrate
    /// that serving (connection handlers + batched engine passes) and
    /// eval sweeps share.
    pub exec: ExecConfig,
    /// Deterministic fault injection (JSON `"fault_plan"` object or
    /// `serve --fault-plan`).  [`FaultPlan::none`] — the default —
    /// injects nothing and serving is bit-identical to a plan-free
    /// build.
    pub fault_plan: FaultPlan,
    /// Transient-failure retry budget: how many times the scheduler
    /// replays a job whose step failed with a *transient* error (the
    /// failed sequence is rolled back through the preemption path
    /// first).  0 disables retries; fatal errors never retry.
    pub max_step_retries: u32,
    /// Base backoff before a retry is re-admitted, in milliseconds;
    /// doubles per attempt (bounded exponential).
    pub retry_backoff_ms: u64,
    /// Graceful degradation under sustained pressure.  Off (the
    /// default) keeps admission behavior bit-identical; on, the
    /// composer hysteretically switches new admissions to
    /// base-model-only and, under severe pressure, sheds submissions
    /// with `overloaded` + a retry-after hint.
    pub degrade: bool,
    /// Queue depth at which pressure samples count toward entering
    /// degraded (base-only) admissions.
    pub degrade_queue_hiwater: usize,
    /// Queue depth at which pressure counts as severe (shed mode).
    pub degrade_shed_hiwater: usize,
    /// Consecutive pressured composer samples before escalating a mode.
    pub degrade_enter_ticks: u32,
    /// Consecutive calm samples before stepping back down (hysteresis).
    pub degrade_exit_ticks: u32,
    /// Step retries observed within one sample window that count as a
    /// retry storm (a pressure signal on their own).
    pub degrade_retry_storm: u32,
    /// Retry-after hint (milliseconds) carried by shed responses.
    pub degrade_retry_after_ms: u64,
    /// Read-timeout tick for an idle connection, ms (shutdown/cancel
    /// observation cadence; was a hardcoded 200ms).
    pub idle_poll_ms: u64,
    /// Read-timeout tick while v2 sessions stream on a connection, ms
    /// (event pump cadence; was a hardcoded 15ms).
    pub stream_poll_ms: u64,
    /// Structured per-request tracing (`serve --trace`).  Off (the
    /// default) is bit-identical serving: the tracer never allocates
    /// and every hook is a single branch.  The always-on metrics
    /// registry and flight recorder are unaffected by this knob.
    pub obs_trace: bool,
    /// Export each finished trace as NDJSON into this directory
    /// (`serve --trace-dir`); "" disables file export.  Setting it via
    /// the CLI implies `obs_trace`.
    pub obs_trace_dir: String,
    /// Finished trace timelines retained in memory for the v2 `trace`
    /// wire op (oldest evicted beyond this).
    pub obs_trace_keep: usize,
    /// Flight-recorder ring capacity per subsystem (recent events kept
    /// for fault/degrade post-mortem dumps).
    pub obs_flight_events: usize,
}

impl Default for DeployConfig {
    fn default() -> Self {
        let spec = SpecConfig::default();
        DeployConfig {
            artifacts_dir: "artifacts".into(),
            base_model: "qwq-sim".into(),
            small_model: "r1-sim".into(),
            addr: "127.0.0.1:7878".into(),
            kv_block_size: 32,
            kv_seqs_per_model: 8,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            temperature: 0.6,
            seed: 0x5EED,
            scheme: Scheme::SpecReason,
            threshold: 7,
            first_n_base: 0,
            token_budget: spec.token_budget,
            answer_tokens: spec.answer_tokens,
            verify_template_len: spec.verify_template_len,
            draft_k: spec.draft_k,
            lookahead_k: spec.lookahead_k,
            max_queue: 64,
            io_threads: 4,
            max_batch: 1,
            preempt: true,
            replicas: 1,
            replica_affinity: true,
            replica_spill_watermark: 0,
            slo_ms: 0,
            exec: ExecConfig::default(),
            fault_plan: FaultPlan::none(),
            max_step_retries: 3,
            retry_backoff_ms: 5,
            degrade: false,
            degrade_queue_hiwater: 48,
            degrade_shed_hiwater: 56,
            degrade_enter_ticks: 3,
            degrade_exit_ticks: 50,
            degrade_retry_storm: 4,
            degrade_retry_after_ms: 250,
            idle_poll_ms: 200,
            stream_poll_ms: 15,
            obs_trace: false,
            obs_trace_dir: String::new(),
            obs_trace_keep: 64,
            obs_flight_events: 256,
        }
    }
}

impl DeployConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<DeployConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<DeployConfig> {
        let j = Json::parse(text).context("parsing deploy config JSON")?;
        let mut c = DeployConfig::default();
        if let Some(v) = j.get("artifacts_dir").as_str() {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("base_model").as_str() {
            c.base_model = v.to_string();
        }
        if let Some(v) = j.get("small_model").as_str() {
            c.small_model = v.to_string();
        }
        if let Some(v) = j.get("addr").as_str() {
            c.addr = v.to_string();
        }
        if let Some(v) = j.get("kv_block_size").as_usize() {
            c.kv_block_size = v;
        }
        if let Some(v) = j.get("kv_seqs_per_model").as_usize() {
            c.kv_seqs_per_model = v;
        }
        if let Some(v) = j.get("prefix_cache").as_bool() {
            c.prefix_cache = v;
        }
        if let Some(v) = j.get("prefix_cache_blocks").as_usize() {
            c.prefix_cache_blocks = v;
        }
        if let Some(v) = j.get("temperature").as_f64() {
            c.temperature = v as f32;
        }
        if let Some(v) = j.get("seed").as_usize() {
            c.seed = v as u64;
        }
        if let Some(v) = j.get("scheme").as_str() {
            c.scheme = Scheme::parse(v)?;
        }
        if let Some(v) = j.get("threshold").as_usize() {
            anyhow::ensure!(v <= 9, "threshold must be 0..=9");
            c.threshold = v as u8;
        }
        if let Some(v) = j.get("first_n_base").as_usize() {
            c.first_n_base = v;
        }
        if let Some(v) = j.get("token_budget").as_usize() {
            c.token_budget = v;
        }
        if let Some(v) = j.get("answer_tokens").as_usize() {
            c.answer_tokens = v;
        }
        if let Some(v) = j.get("verify_template_len").as_usize() {
            c.verify_template_len = v;
        }
        if let Some(v) = j.get("draft_k").as_usize() {
            anyhow::ensure!(v >= 1, "draft_k must be >= 1");
            c.draft_k = v;
        }
        if let Some(v) = j.get("lookahead_k").as_usize() {
            c.lookahead_k = v;
        }
        if let Some(v) = j.get("max_queue").as_usize() {
            c.max_queue = v;
        }
        if let Some(v) = j.get("io_threads").as_usize() {
            anyhow::ensure!(v >= 1, "io_threads must be >= 1");
            c.io_threads = v;
        }
        if let Some(v) = j.get("max_batch").as_usize() {
            c.max_batch = v;
        }
        if let Some(v) = j.get("preempt").as_bool() {
            c.preempt = v;
        }
        if let Some(v) = j.get("replicas").as_usize() {
            c.replicas = v;
        }
        if let Some(v) = j.get("replica_affinity").as_bool() {
            c.replica_affinity = v;
        }
        if let Some(v) = j.get("replica_spill_watermark").as_usize() {
            c.replica_spill_watermark = v;
        }
        if let Some(v) = j.get("slo_ms").as_usize() {
            c.slo_ms = v as u64;
        }
        if let Some(v) = j.get("threads").as_usize() {
            c.exec.workers = Some(v);
        }
        if let Some(v) = j.get("pin").as_str() {
            c.exec.pin = PinPolicy::parse(v)?;
        }
        // Fault injection: a JSON object or the compact string form.
        match j.get("fault_plan") {
            Json::Null => {}
            Json::Str(s) => c.fault_plan = FaultPlan::parse(s)?,
            obj => c.fault_plan = FaultPlan::from_json(obj)?,
        }
        if let Some(v) = j.get("max_step_retries").as_usize() {
            c.max_step_retries = v as u32;
        }
        if let Some(v) = j.get("retry_backoff_ms").as_usize() {
            c.retry_backoff_ms = v as u64;
        }
        if let Some(v) = j.get("degrade").as_bool() {
            c.degrade = v;
        }
        if let Some(v) = j.get("degrade_queue_hiwater").as_usize() {
            c.degrade_queue_hiwater = v;
        }
        if let Some(v) = j.get("degrade_shed_hiwater").as_usize() {
            c.degrade_shed_hiwater = v;
        }
        if let Some(v) = j.get("degrade_enter_ticks").as_usize() {
            c.degrade_enter_ticks = v as u32;
        }
        if let Some(v) = j.get("degrade_exit_ticks").as_usize() {
            c.degrade_exit_ticks = v as u32;
        }
        if let Some(v) = j.get("degrade_retry_storm").as_usize() {
            c.degrade_retry_storm = v as u32;
        }
        if let Some(v) = j.get("degrade_retry_after_ms").as_usize() {
            c.degrade_retry_after_ms = v as u64;
        }
        if let Some(v) = j.get("idle_poll_ms").as_usize() {
            c.idle_poll_ms = v as u64;
        }
        if let Some(v) = j.get("stream_poll_ms").as_usize() {
            c.stream_poll_ms = v as u64;
        }
        if let Some(v) = j.get("obs_trace").as_bool() {
            c.obs_trace = v;
        }
        if let Some(v) = j.get("obs_trace_dir").as_str() {
            c.obs_trace_dir = v.to_string();
        }
        if let Some(v) = j.get("obs_trace_keep").as_usize() {
            c.obs_trace_keep = v;
        }
        if let Some(v) = j.get("obs_flight_events").as_usize() {
            c.obs_flight_events = v;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        // Exhaustive destructure: adding a DeployConfig field without
        // deciding its validation story fails to compile here (and
        // trips speclint's d4-drift gate).  Fields bound to `_` are
        // free-form by design: any value a caller can express is legal.
        let DeployConfig {
            artifacts_dir: _,
            base_model: _,
            small_model: _,
            addr: _,
            kv_block_size: _,
            kv_seqs_per_model: _,
            prefix_cache: _,
            prefix_cache_blocks: _,
            temperature: _,
            seed: _,
            scheme: _,
            threshold: _,
            first_n_base: _,
            token_budget: _,
            answer_tokens: _,
            verify_template_len: _,
            draft_k: _,
            lookahead_k: _,
            max_queue: _,
            io_threads: _,
            max_batch: _,
            preempt: _,
            replicas: _,
            replica_affinity: _,
            replica_spill_watermark: _,
            slo_ms: _,
            exec: _,
            fault_plan: _,
            max_step_retries: _,
            retry_backoff_ms: _,
            degrade: _,
            degrade_queue_hiwater: _,
            degrade_shed_hiwater: _,
            degrade_enter_ticks: _,
            degrade_exit_ticks: _,
            degrade_retry_storm: _,
            degrade_retry_after_ms: _,
            idle_poll_ms: _,
            stream_poll_ms: _,
            obs_trace: _,
            obs_trace_dir: _,
            obs_trace_keep: _,
            obs_flight_events: _,
        } = self;
        anyhow::ensure!(self.token_budget >= 16, "token_budget too small");
        anyhow::ensure!(self.kv_block_size >= 1, "kv_block_size must be >= 1");
        anyhow::ensure!(
            self.base_model != self.small_model,
            "base and small model must differ"
        );
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(self.replicas >= 1, "replicas must be >= 1");
        anyhow::ensure!(
            self.exec.workers != Some(0),
            "threads must be >= 1 (omit it for auto: SPECREASON_BENCH_THREADS or \
             available parallelism)"
        );
        self.fault_plan.validate()?;
        anyhow::ensure!(self.idle_poll_ms >= 1, "idle_poll_ms must be >= 1");
        anyhow::ensure!(self.stream_poll_ms >= 1, "stream_poll_ms must be >= 1");
        anyhow::ensure!(
            self.degrade_shed_hiwater >= self.degrade_queue_hiwater,
            "degrade_shed_hiwater must be >= degrade_queue_hiwater"
        );
        anyhow::ensure!(
            self.degrade_enter_ticks >= 1 && self.degrade_exit_ticks >= 1,
            "degrade enter/exit ticks must be >= 1"
        );
        anyhow::ensure!(self.obs_trace_keep >= 1, "obs_trace_keep must be >= 1");
        anyhow::ensure!(self.obs_flight_events >= 1, "obs_flight_events must be >= 1");
        // Incoherent knob combos are structured `bad_request` errors
        // (`code_of` classifies them; the server surfaces the code on
        // the wire) rather than silently accepted contradictions.
        if self.lookahead_k > 0 && !self.scheme.speculates_steps() {
            return Err(crate::scheduler::coded(
                crate::scheduler::ErrorCode::BadRequest,
                format!(
                    "lookahead_k = {} needs a step-speculating scheme, but '{}' pins \
                     generation base-only — there is no speculation to pipeline \
                     (set lookahead_k to 0 or use spec-reason / spec-reason+decode)",
                    self.lookahead_k,
                    self.scheme.name()
                ),
            ));
        }
        if self.prefix_cache_blocks > 0 && !self.prefix_cache {
            return Err(crate::scheduler::coded(
                crate::scheduler::ErrorCode::BadRequest,
                format!(
                    "prefix_cache_blocks = {} is set while prefix_cache is false; the \
                     budget only applies to the shared-prefix cache (enable \
                     prefix_cache or drop the budget)",
                    self.prefix_cache_blocks
                ),
            ));
        }
        Ok(())
    }

    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            artifacts_dir: self.artifacts_dir.clone(),
            models: vec![self.base_model.clone(), self.small_model.clone()],
            testbed: if crate::semantics::ModelClass::of(&self.base_model)
                == crate::semantics::ModelClass::Large
            {
                Testbed::A100x4
            } else {
                Testbed::A6000x2
            },
            kv_block_size: self.kv_block_size,
            kv_seqs_per_model: self.kv_seqs_per_model,
            prefix_cache: self.prefix_cache,
            prefix_cache_blocks: self.prefix_cache_blocks,
            temperature: self.temperature,
            fault_plan: self.fault_plan.clone(),
        }
    }

    pub fn spec_config(&self) -> SpecConfig {
        SpecConfig {
            scheme: self.scheme,
            policy: AcceptancePolicy::Static { threshold: self.threshold },
            first_n_base: self.first_n_base,
            token_budget: self.token_budget,
            answer_tokens: self.answer_tokens,
            verify_template_len: self.verify_template_len,
            draft_k: self.draft_k,
            lookahead_k: self.lookahead_k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        DeployConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_overrides() {
        let c = DeployConfig::from_json_str(
            r#"{"base_model": "skywork-sim", "small_model": "zr1-sim",
                "scheme": "spec-reason+decode", "threshold": 5,
                "token_budget": 512, "temperature": 0.8}"#,
        )
        .unwrap();
        assert_eq!(c.base_model, "skywork-sim");
        assert_eq!(c.scheme, Scheme::SpecReasonPlusDecode);
        assert_eq!(c.threshold, 5);
        assert_eq!(c.token_budget, 512);
        assert!((c.temperature - 0.8).abs() < 1e-6);
        // untouched fields keep defaults
        assert_eq!(c.addr, "127.0.0.1:7878");
        assert_eq!(c.max_batch, 1);
        assert!(c.preempt);
        assert_eq!(c.slo_ms, 0);
        assert_eq!(c.seed, 0x5EED);
    }

    #[test]
    fn parses_default_seed() {
        let c = DeployConfig::from_json_str(r#"{"seed": 4242}"#).unwrap();
        assert_eq!(c.seed, 4242);
    }

    #[test]
    fn parses_prefix_cache_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"prefix_cache": true, "prefix_cache_blocks": 128}"#,
        )
        .unwrap();
        assert!(c.prefix_cache);
        assert_eq!(c.prefix_cache_blocks, 128);
        let e = c.engine_config();
        assert!(e.prefix_cache);
        assert_eq!(e.prefix_cache_blocks, 128);
        // Default: off, auto budget — bit-identical serving semantics.
        let d = DeployConfig::default();
        assert!(!d.prefix_cache);
        assert_eq!(d.prefix_cache_blocks, 0);
        assert!(!d.engine_config().prefix_cache);
    }

    #[test]
    fn parses_scheduler_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"max_batch": 8, "preempt": false, "slo_ms": 30000, "max_queue": 128}"#,
        )
        .unwrap();
        assert_eq!(c.max_batch, 8);
        assert!(!c.preempt);
        assert_eq!(c.slo_ms, 30000);
        assert_eq!(c.max_queue, 128);
        assert!(DeployConfig::from_json_str(r#"{"max_batch": 0}"#).is_err());
    }

    #[test]
    fn parses_replica_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"replicas": 4, "replica_affinity": false,
                "replica_spill_watermark": 16}"#,
        )
        .unwrap();
        assert_eq!(c.replicas, 4);
        assert!(!c.replica_affinity);
        assert_eq!(c.replica_spill_watermark, 16);
        // Default: one replica (bit-identical single-scheduler path),
        // affinity armed for when replicas rise, spill off.
        let d = DeployConfig::default();
        assert_eq!(d.replicas, 1);
        assert!(d.replica_affinity);
        assert_eq!(d.replica_spill_watermark, 0);
        assert!(DeployConfig::from_json_str(r#"{"replicas": 0}"#).is_err());
    }

    #[test]
    fn parses_exec_knobs() {
        let c = DeployConfig::from_json_str(r#"{"threads": 6, "pin": "pinned"}"#).unwrap();
        assert_eq!(c.exec.workers, Some(6));
        assert_eq!(c.exec.pin, PinPolicy::Pinned);
        // Default: auto-sized, floating.
        let d = DeployConfig::default();
        assert_eq!(d.exec.workers, None);
        assert_eq!(d.exec.pin, PinPolicy::Floating);
        // threads=0 is a hard error, not a silent fallback.
        let err = DeployConfig::from_json_str(r#"{"threads": 0}"#).unwrap_err();
        assert!(format!("{err:#}").contains("threads must be >= 1"));
        assert!(DeployConfig::from_json_str(r#"{"pin": "warp"}"#).is_err());
    }

    #[test]
    fn parses_fault_and_retry_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"fault_plan": {"seed": 9, "rate": 0.02, "sites": ["engine_op", "kv"]},
                "max_step_retries": 5, "retry_backoff_ms": 2}"#,
        )
        .unwrap();
        assert_eq!(c.fault_plan.seed, 9);
        assert!((c.fault_plan.rate - 0.02).abs() < 1e-12);
        assert_eq!(c.fault_plan.sites.len(), 2);
        assert_eq!(c.max_step_retries, 5);
        assert_eq!(c.retry_backoff_ms, 2);
        assert_eq!(c.engine_config().fault_plan, c.fault_plan);
        // Compact string form is accepted too.
        let s = DeployConfig::from_json_str(
            r#"{"fault_plan": "seed=3,rate=0.1,sites=batch"}"#,
        )
        .unwrap();
        assert_eq!(s.fault_plan.seed, 3);
        // Default: inert plan, retries on, degradation off.
        let d = DeployConfig::default();
        assert!(d.fault_plan.is_none());
        assert_eq!(d.max_step_retries, 3);
        assert!(!d.degrade);
        assert!(DeployConfig::from_json_str(r#"{"fault_plan": {"rate": 2.0}}"#).is_err());
    }

    #[test]
    fn parses_degrade_and_poll_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"degrade": true, "degrade_queue_hiwater": 10,
                "degrade_shed_hiwater": 20, "degrade_enter_ticks": 2,
                "degrade_exit_ticks": 4, "degrade_retry_storm": 3,
                "degrade_retry_after_ms": 100,
                "idle_poll_ms": 50, "stream_poll_ms": 5}"#,
        )
        .unwrap();
        assert!(c.degrade);
        assert_eq!(c.degrade_queue_hiwater, 10);
        assert_eq!(c.degrade_shed_hiwater, 20);
        assert_eq!(c.degrade_enter_ticks, 2);
        assert_eq!(c.degrade_exit_ticks, 4);
        assert_eq!(c.degrade_retry_storm, 3);
        assert_eq!(c.degrade_retry_after_ms, 100);
        assert_eq!(c.idle_poll_ms, 50);
        assert_eq!(c.stream_poll_ms, 5);
        // Defaults match the previously hardcoded pump cadences.
        let d = DeployConfig::default();
        assert_eq!(d.idle_poll_ms, 200);
        assert_eq!(d.stream_poll_ms, 15);
        assert!(DeployConfig::from_json_str(r#"{"stream_poll_ms": 0}"#).is_err());
        assert!(DeployConfig::from_json_str(
            r#"{"degrade_queue_hiwater": 9, "degrade_shed_hiwater": 3}"#
        )
        .is_err());
    }

    #[test]
    fn parses_obs_knobs() {
        let c = DeployConfig::from_json_str(
            r#"{"obs_trace": true, "obs_trace_dir": "/tmp/traces",
                "obs_trace_keep": 8, "obs_flight_events": 32}"#,
        )
        .unwrap();
        assert!(c.obs_trace);
        assert_eq!(c.obs_trace_dir, "/tmp/traces");
        assert_eq!(c.obs_trace_keep, 8);
        assert_eq!(c.obs_flight_events, 32);
        // Default: tracing off (bit-identical serving), bounded rings.
        let d = DeployConfig::default();
        assert!(!d.obs_trace);
        assert!(d.obs_trace_dir.is_empty());
        assert_eq!(d.obs_trace_keep, 64);
        assert_eq!(d.obs_flight_events, 256);
        assert!(DeployConfig::from_json_str(r#"{"obs_trace_keep": 0}"#).is_err());
        assert!(DeployConfig::from_json_str(r#"{"obs_flight_events": 0}"#).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(DeployConfig::from_json_str(r#"{"threshold": 12}"#).is_err());
        assert!(DeployConfig::from_json_str(r#"{"scheme": "warp"}"#).is_err());
        assert!(DeployConfig::from_json_str(
            r#"{"base_model": "x", "small_model": "x"}"#
        )
        .is_err());
        assert!(DeployConfig::from_json_str("not json").is_err());
    }

    #[test]
    fn parses_lookahead_knob() {
        let c = DeployConfig::from_json_str(r#"{"lookahead_k": 3}"#).unwrap();
        assert_eq!(c.lookahead_k, 3);
        assert_eq!(c.spec_config().lookahead_k, 3);
        // Default stays serial.
        assert_eq!(DeployConfig::default().lookahead_k, 0);
    }

    #[test]
    fn rejects_lookahead_with_base_only_scheme() {
        // A base-only pinned scheme leaves nothing to pipeline.
        let err = DeployConfig::from_json_str(r#"{"scheme": "vanilla-base", "lookahead_k": 2}"#)
            .unwrap_err();
        assert_eq!(
            crate::scheduler::code_of(&err),
            crate::scheduler::ErrorCode::BadRequest
        );
        // Non-step-speculating decode-only scheme is equally incoherent.
        assert!(
            DeployConfig::from_json_str(r#"{"scheme": "spec-decode", "lookahead_k": 1}"#).is_err()
        );
        // Step-speculating schemes accept the knob.
        assert!(DeployConfig::from_json_str(
            r#"{"scheme": "spec-reason+decode", "lookahead_k": 4}"#
        )
        .is_ok());
    }

    #[test]
    fn rejects_prefix_cache_blocks_without_prefix_cache() {
        let err = DeployConfig::from_json_str(r#"{"prefix_cache_blocks": 128}"#).unwrap_err();
        assert_eq!(
            crate::scheduler::code_of(&err),
            crate::scheduler::ErrorCode::BadRequest
        );
        assert!(DeployConfig::from_json_str(
            r#"{"prefix_cache": true, "prefix_cache_blocks": 128}"#
        )
        .is_ok());
    }

    #[test]
    fn large_base_selects_a100_testbed() {
        let c = DeployConfig::from_json_str(r#"{"base_model": "r1-70b-sim"}"#).unwrap();
        assert_eq!(c.engine_config().testbed, Testbed::A100x4);
    }
}
