//! Parallel sweep engine: fan (cell × query × sample) evaluation across
//! the shared thread pool with deterministic merging.
//!
//! The paper's headline figures are all produced by sweeping
//! scheme × dataset × combo × threshold grids, and every (query, sample)
//! unit inside a grid is independent: [`run_query`] is a pure function of
//! (oracle, query seed, sample), so the grid is embarrassingly parallel.
//! A [`Sweep`] expands its cells into [`WorkItem`]s, executes them across
//! the process-wide [`ThreadPool`] (thread count from
//! `SPECREASON_BENCH_THREADS`, default = available parallelism), and
//! folds the per-item outcomes back **in plan order**, so the merged
//! [`Aggregate`]s are bit-identical to a sequential run at any thread
//! count — `run_sim_seq` exists precisely so tests can assert that.
//!
//! The real-engine path reuses the same planner and merge code but
//! executes items sequentially: the paper's deployment serializes the two
//! colocated models on shared GPUs, so there is no intra-engine
//! parallelism to exploit (batched server scheduling is tracked as a
//! ROADMAP follow-on).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::{run_query, QueryOutcome, RealBackend, SimBackend};
use crate::engine::Engine;
use crate::metrics::{Aggregate, GpuClock};
use crate::semantics::{ModelClass, Oracle, Query};
use crate::util::threadpool::ThreadPool;

use super::{
    arch_name, bench_queries, bench_real, bench_samples, label, testbed_for, Cell, CellResult,
};

/// One independent unit of sweep work: run `cell_id`'s scheme on query
/// `query_idx`, pass@1 repetition `sample`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkItem {
    pub cell_id: usize,
    pub query_idx: usize,
    pub sample: usize,
}

/// A planned grid of evaluation cells sharing (n_queries, samples, seed).
#[derive(Debug, Clone)]
pub struct Sweep {
    cells: Vec<Cell>,
    n_queries: usize,
    samples: usize,
    seed: u64,
}

impl Sweep {
    pub fn new(n_queries: usize, samples: usize, seed: u64) -> Sweep {
        Sweep { cells: Vec::new(), n_queries, samples, seed }
    }

    /// Sweep sized from the `SPECREASON_BENCH_QUERIES` /
    /// `SPECREASON_BENCH_SAMPLES` env knobs (the bench defaults).
    pub fn bench(seed: u64) -> Sweep {
        Sweep::new(bench_queries(), bench_samples(), seed)
    }

    /// Add a cell to the grid; returns its id (the index of its
    /// [`CellResult`] in every `run_*` output).
    pub fn cell(&mut self, cell: Cell) -> usize {
        self.cells.push(cell);
        self.cells.len() - 1
    }

    pub fn cells(&self) -> &[Cell] {
        &self.cells
    }

    pub fn n_queries(&self) -> usize {
        self.n_queries
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Work items per cell.
    pub fn items_per_cell(&self) -> usize {
        self.n_queries * self.samples
    }

    /// Total work items in the grid.
    pub fn len(&self) -> usize {
        self.cells.len() * self.items_per_cell()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the grid into work items, cell-major then query-major then
    /// sample — exactly the iteration order of the sequential path, which
    /// is what makes in-order merging bit-identical.
    pub fn plan(&self) -> Vec<WorkItem> {
        let mut items = Vec::with_capacity(self.len());
        for cell_id in 0..self.cells.len() {
            for query_idx in 0..self.n_queries {
                for sample in 0..self.samples {
                    items.push(WorkItem { cell_id, query_idx, sample });
                }
            }
        }
        items
    }

    /// Run on the simulator across the shared pool (default thread count).
    pub fn run_sim(&self, oracle: &Oracle) -> Result<Vec<CellResult>> {
        self.run_sim_on_pool(oracle, &shared_pool())
    }

    /// Run on the simulator across a dedicated pool of `threads` workers
    /// (`0` = the shared pool at the default thread count).
    pub fn run_sim_threads(&self, oracle: &Oracle, threads: usize) -> Result<Vec<CellResult>> {
        if threads == 0 {
            return self.run_sim(oracle);
        }
        self.run_sim_on_pool(oracle, &ThreadPool::new(threads))
    }

    /// Pure-sequential reference path: a plain loop over the plan with no
    /// pool involved. The parallel paths must match this bit-for-bit.
    pub fn run_sim_seq(&self, oracle: &Oracle) -> Result<Vec<CellResult>> {
        let outs = run_items_sim(oracle, &self.cells, self.seed, &self.plan())?;
        Ok(self.collect(outs))
    }

    fn run_sim_on_pool(&self, oracle: &Oracle, pool: &ThreadPool) -> Result<Vec<CellResult>> {
        let items = self.plan();
        if items.is_empty() {
            return Ok(self.collect(Vec::new()));
        }
        // Chunk items so per-job channel overhead amortizes over many
        // run_query calls while keeping enough chunks for load balance.
        let per_chunk = chunk_size(items.len(), pool.size());
        let chunks: Vec<Vec<WorkItem>> = items.chunks(per_chunk).map(|c| c.to_vec()).collect();
        let ctx = Arc::new(SimCtx {
            oracle: oracle.clone(),
            cells: self.cells.clone(),
            seed: self.seed,
        });
        let results = pool
            .map(chunks, move |_, chunk: Vec<WorkItem>| {
                run_items_sim(&ctx.oracle, &ctx.cells, ctx.seed, &chunk)
            })
            .map_err(|e| anyhow::anyhow!("sweep pool unavailable: {e}"))?;
        // map() returned chunk results in submission order; flatten back
        // into plan order (first error in plan order wins).
        let mut outs = Vec::with_capacity(self.len());
        for chunk in results {
            outs.extend(chunk?);
        }
        Ok(self.collect(outs))
    }

    /// Run on the real engine (must have every cell's models loaded).
    /// Items execute sequentially — the engine serializes the colocated
    /// models on the (simulated) GPUs — but planning and merging are the
    /// same code as the parallel path.
    pub fn run_real(&self, engine: &Engine, oracle: &Oracle) -> Result<Vec<CellResult>> {
        let mut outs = Vec::with_capacity(self.len());
        let mut cached: Option<(usize, usize, Arc<Query>)> = None;
        for item in self.plan() {
            let cell = &self.cells[item.cell_id];
            let stale = match &cached {
                Some((c, qi, _)) => *c != item.cell_id || *qi != item.query_idx,
                None => true,
            };
            if stale {
                let q = super::qcache::cached_query(cell.dataset, self.seed, item.query_idx);
                cached = Some((item.cell_id, item.query_idx, q));
            }
            let q: &Query = &cached.as_ref().expect("query cached").2;
            let mut b = RealBackend::new(engine, &cell.combo.small, &cell.combo.base);
            let out = run_query(oracle, q, &cell.combo, &cell.cfg, &mut b, item.sample)?;
            b.release()?;
            outs.push(out);
        }
        Ok(self.collect(outs))
    }

    /// Honor the bench env: simulator by default, real engine with
    /// `SPECREASON_BENCH_REAL=1` and a caller-provided engine.
    pub fn run_bench(&self, oracle: &Oracle, engine: Option<&Engine>) -> Result<Vec<CellResult>> {
        match engine {
            Some(e) if bench_real() => self.run_real(e, oracle),
            _ => self.run_sim(oracle),
        }
    }

    /// Fold per-item outcomes (in plan order) into per-cell results.
    /// Aggregation borrows each outcome's metrics — nothing is cloned —
    /// and pushes them in exactly the sequential order, which is what
    /// makes the parallel path bit-identical to `run_sim_seq`.
    fn collect(&self, outs: Vec<QueryOutcome>) -> Vec<CellResult> {
        debug_assert_eq!(outs.len(), self.len());
        let per_cell = self.items_per_cell();
        let mut it = outs.into_iter();
        self.cells
            .iter()
            .map(|cell| {
                let outcomes: Vec<QueryOutcome> = it.by_ref().take(per_cell).collect();
                let mut agg = Aggregate::default();
                for o in &outcomes {
                    agg.push(&o.metrics);
                }
                CellResult { cell_label: label(cell), agg, outcomes }
            })
            .collect()
    }
}

struct SimCtx {
    oracle: Oracle,
    cells: Vec<Cell>,
    seed: u64,
}

/// Execute a run of work items on the simulator. Pure in (oracle, cells,
/// seed, items): every call with the same arguments produces the same
/// outcomes regardless of thread, which the determinism tests assert.
///
/// Queries come from the process-wide cross-cell cache
/// ([`qcache`](super::qcache)): cells sharing a `(dataset, seed)` reuse
/// one generated `Query` per index instead of regenerating it, with a
/// local one-entry memo so adjacent samples skip the cache lock;
/// `TraceGenerator::query` is pure, so this is purely a work saving, not
/// a behavior change.
fn run_items_sim(
    oracle: &Oracle,
    cells: &[Cell],
    seed: u64,
    items: &[WorkItem],
) -> Result<Vec<QueryOutcome>> {
    let mut outs = Vec::with_capacity(items.len());
    let mut cached: Option<(usize, usize, Arc<Query>)> = None;
    for item in items {
        let cell = &cells[item.cell_id];
        let stale = match &cached {
            Some((c, qi, _)) => *c != item.cell_id || *qi != item.query_idx,
            None => true,
        };
        if stale {
            let q = super::qcache::cached_query(cell.dataset, seed, item.query_idx);
            cached = Some((item.cell_id, item.query_idx, q));
        }
        let q: &Query = &cached.as_ref().expect("query cached").2;
        let clock = GpuClock::new(testbed_for(&cell.combo));
        let small_arch = arch_name(ModelClass::of(&cell.combo.small));
        let base_arch = arch_name(ModelClass::of(&cell.combo.base));
        let mut b = SimBackend::new(clock, small_arch, base_arch);
        outs.push(run_query(oracle, q, &cell.combo, &cell.cfg, &mut b, item.sample)?);
    }
    Ok(outs)
}

fn chunk_size(items: usize, workers: usize) -> usize {
    // ~8 chunks per worker balances channel overhead against stragglers.
    let target_chunks = workers.max(1) * 8;
    ((items + target_chunks - 1) / target_chunks).max(1)
}

/// Worker count for eval sweeps: `SPECREASON_BENCH_THREADS` if set (> 0),
/// else the machine's available parallelism.
pub fn bench_threads() -> usize {
    std::env::var("SPECREASON_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

static SHARED: Mutex<Option<Arc<ThreadPool>>> = Mutex::new(None);

/// The process-wide sweep pool, created on first use with
/// [`bench_threads`] workers and shared by every sweep (and any other
/// caller that wants parallel helpers, e.g. the fig7 scoring loop).
pub fn shared_pool() -> Arc<ThreadPool> {
    let mut guard = SHARED.lock().unwrap();
    if let Some(pool) = guard.as_ref() {
        return Arc::clone(pool);
    }
    let pool = Arc::new(ThreadPool::new(bench_threads()));
    *guard = Some(Arc::clone(&pool));
    pool
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{AcceptancePolicy, Combo, Scheme, SpecConfig};
    use crate::semantics::Dataset;

    fn grid() -> Sweep {
        let mut sw = Sweep::new(4, 2, 7);
        for ds in [Dataset::Aime, Dataset::Math500] {
            for scheme in [Scheme::SpecReason, Scheme::VanillaBase] {
                sw.cell(Cell {
                    dataset: ds,
                    scheme,
                    combo: Combo::new("qwq-sim", "r1-sim"),
                    cfg: SpecConfig {
                        scheme,
                        policy: AcceptancePolicy::Static { threshold: 7 },
                        ..Default::default()
                    },
                });
            }
        }
        sw
    }

    #[test]
    fn plan_is_cell_major_query_major_sample_minor() {
        let sw = grid();
        let plan = sw.plan();
        assert_eq!(plan.len(), 4 * 4 * 2);
        assert_eq!(plan[0], WorkItem { cell_id: 0, query_idx: 0, sample: 0 });
        assert_eq!(plan[1], WorkItem { cell_id: 0, query_idx: 0, sample: 1 });
        assert_eq!(plan[2], WorkItem { cell_id: 0, query_idx: 1, sample: 0 });
        assert_eq!(plan[8], WorkItem { cell_id: 1, query_idx: 0, sample: 0 });
        assert_eq!(
            plan[31],
            WorkItem { cell_id: 3, query_idx: 3, sample: 1 }
        );
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        let oracle = Oracle::default();
        let sw = grid();
        let seq = sw.run_sim_seq(&oracle).unwrap();
        let par = sw.run_sim_threads(&oracle, 3).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.cell_label, b.cell_label);
            assert_eq!(a.agg, b.agg, "{}: aggregate diverged", a.cell_label);
            assert_eq!(a.mean_gpu().to_bits(), b.mean_gpu().to_bits());
            for (oa, ob) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(oa.metrics.gpu_secs.to_bits(), ob.metrics.gpu_secs.to_bits());
                assert_eq!(oa.metrics.answer_correct, ob.metrics.answer_correct);
                assert_eq!(oa.metrics.steps_accepted, ob.metrics.steps_accepted);
            }
        }
    }

    #[test]
    fn empty_sweep_returns_no_results() {
        let oracle = Oracle::default();
        let sw = Sweep::new(4, 2, 7);
        assert!(sw.is_empty());
        assert!(sw.run_sim_threads(&oracle, 2).unwrap().is_empty());
        assert!(sw.run_sim_seq(&oracle).unwrap().is_empty());
    }

    #[test]
    fn chunking_covers_all_items() {
        for (items, workers) in [(1usize, 4usize), (7, 4), (32, 1), (1920, 8), (3, 16)] {
            let c = chunk_size(items, workers);
            assert!(c >= 1);
            // ceil(items / c) chunks reconstruct exactly `items` items.
            let chunks = (items + c - 1) / c;
            assert!(chunks * c >= items);
            assert!((chunks - 1) * c < items);
        }
    }

    #[test]
    fn bench_threads_is_positive() {
        assert!(bench_threads() >= 1);
    }
}
