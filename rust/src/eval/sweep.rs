//! Parallel sweep engine: fan (cell × query × sample) evaluation across
//! the process-wide work-stealing executor with deterministic merging.
//!
//! The paper's headline figures are all produced by sweeping
//! scheme × dataset × combo × threshold grids, and every (query, sample)
//! unit inside a grid is independent: [`run_query`] is a pure function of
//! (oracle, query seed, sample), so the grid is embarrassingly parallel.
//! A [`Sweep`] expands its cells into [`WorkItem`]s, executes them as
//! **adaptively-sized chunks** on the shared [`Executor`] (worker count
//! from `SPECREASON_BENCH_THREADS` / `--threads`, default = available
//! parallelism), and folds the per-item outcomes back **in plan order**,
//! so the merged [`Aggregate`]s are bit-identical to a sequential run at
//! any worker count and under any steal order — `run_sim_seq` exists
//! precisely so tests can assert that.
//!
//! Chunking is *guided* rather than static: head chunks are large
//! (amortizing dispatch over many `run_query` calls) and shrink
//! geometrically toward per-item tail chunks, so a long-tailed final
//! cell (AIME plans) spreads across workers via stealing instead of
//! straggling on whichever worker drew the last fat chunk.
//!
//! The real-engine path reuses the same planner, chunker and merge code
//! over an [`EnginePool`] (one engine per worker, round-robin lease):
//! each chunk leases an engine for its duration, each engine serializes
//! its own colocated model pair exactly like the paper's deployment, and
//! the deterministic (GPU-clock) metrics stay bit-identical at any pool
//! size.  [`Sweep::run_real`] with a single engine remains the serial
//! reference.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{run_query, QueryOutcome, RealBackend, SimBackend};
use crate::engine::Engine;
use crate::exec::{EnginePool, Executor};
use crate::metrics::{Aggregate, GpuClock};
use crate::semantics::{ModelClass, Oracle, Query};

use super::{
    arch_name, bench_queries, bench_real, bench_samples, label, testbed_for, Cell, CellResult,
};

/// One independent unit of sweep work: run `cell_id`'s scheme on query
/// `query_idx`, pass@1 repetition `sample`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkItem {
    pub cell_id: usize,
    pub query_idx: usize,
    pub sample: usize,
}

/// A planned grid of evaluation cells sharing (n_queries, samples, seed).
#[derive(Debug, Clone)]
pub struct Sweep {
    cells: Vec<Cell>,
    n_queries: usize,
    samples: usize,
    seed: u64,
}

impl Sweep {
    pub fn new(n_queries: usize, samples: usize, seed: u64) -> Sweep {
        Sweep { cells: Vec::new(), n_queries, samples, seed }
    }

    /// Sweep sized from the `SPECREASON_BENCH_QUERIES` /
    /// `SPECREASON_BENCH_SAMPLES` env knobs (the bench defaults).
    pub fn bench(seed: u64) -> Sweep {
        Sweep::new(bench_queries(), bench_samples(), seed)
    }

    /// Add a cell to the grid; returns its id (the index of its
    /// [`CellResult`] in every `run_*` output).
    pub fn cell(&mut self, cell: Cell) -> usize {
        self.cells.push(cell);
        self.cells.len() - 1
    }

    pub fn cells(&self) -> &[Cell] {
        &self.cells
    }

    pub fn n_queries(&self) -> usize {
        self.n_queries
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Work items per cell.
    pub fn items_per_cell(&self) -> usize {
        self.n_queries * self.samples
    }

    /// Total work items in the grid.
    pub fn len(&self) -> usize {
        self.cells.len() * self.items_per_cell()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the grid into work items, cell-major then query-major then
    /// sample — exactly the iteration order of the sequential path, which
    /// is what makes in-order merging bit-identical.
    pub fn plan(&self) -> Vec<WorkItem> {
        let mut items = Vec::with_capacity(self.len());
        for cell_id in 0..self.cells.len() {
            for query_idx in 0..self.n_queries {
                for sample in 0..self.samples {
                    items.push(WorkItem { cell_id, query_idx, sample });
                }
            }
        }
        items
    }

    /// Run on the simulator across the process-wide executor.
    pub fn run_sim(&self, oracle: &Oracle) -> Result<Vec<CellResult>> {
        self.run_sim_exec(oracle, &crate::exec::try_global()?)
    }

    /// Run on the simulator across a dedicated executor of `threads`
    /// workers (`0` = the shared executor at the default worker count).
    pub fn run_sim_threads(&self, oracle: &Oracle, threads: usize) -> Result<Vec<CellResult>> {
        if threads == 0 {
            return self.run_sim(oracle);
        }
        self.run_sim_exec(oracle, &Executor::new(threads))
    }

    /// Pure-sequential reference path: a plain loop over the plan with no
    /// executor involved. The parallel paths must match this bit-for-bit.
    pub fn run_sim_seq(&self, oracle: &Oracle) -> Result<Vec<CellResult>> {
        let outs = run_items_sim(oracle, &self.cells, self.seed, &self.plan())?;
        Ok(self.collect(outs))
    }

    /// Run on the simulator across an explicit executor (the
    /// determinism suites drive this with adversarial steal orders).
    pub fn run_sim_exec(&self, oracle: &Oracle, exec: &Executor) -> Result<Vec<CellResult>> {
        let items = self.plan();
        if items.is_empty() {
            return Ok(self.collect(Vec::new()));
        }
        let chunks = chunk_plan(items.len(), exec.workers());
        // Borrowed context — scoped_map needs no 'static, no Arc, no
        // clone of the cells.
        let results: Vec<Result<Vec<QueryOutcome>>> =
            exec.scoped_map("sweep:sim", chunks, |_, range: Range<usize>| {
                run_items_sim(oracle, &self.cells, self.seed, &items[range])
            });
        self.flatten(results)
    }

    /// Run on the real engine (must have every cell's models loaded).
    /// Items execute sequentially — one engine serializes its colocated
    /// models — but planning and merging are the same code as the
    /// parallel paths; this is the serial reference for
    /// [`Sweep::run_real_pool`].
    pub fn run_real(&self, engine: &Engine, oracle: &Oracle) -> Result<Vec<CellResult>> {
        let outs = run_items_real(engine, oracle, &self.cells, self.seed, &self.plan())?;
        Ok(self.collect(outs))
    }

    /// Run on an [`EnginePool`]: engine-count-bounded *puller* jobs fan
    /// across the executor, each leasing one pool engine for the whole
    /// sweep and pulling adaptive chunks off a shared cursor, so
    /// `SPECREASON_BENCH_REAL=1` sweeps finally scale with cores while
    /// no executor worker ever parks inside a lease wait (with
    /// `SPECREASON_BENCH_ENGINES=1` on a 16-worker pool, exactly one
    /// worker is busy).  Deterministic (GPU-clock) metrics are
    /// bit-identical to [`Sweep::run_real`] — chunk outcomes are merged
    /// by chunk index, never by completion order; only measured
    /// wall-clock differs.
    pub fn run_real_pool(&self, pool: &EnginePool, oracle: &Oracle) -> Result<Vec<CellResult>> {
        let items = self.plan();
        if items.is_empty() {
            return Ok(self.collect(Vec::new()));
        }
        if pool.size() == 1 {
            let engine = pool.lease();
            let outs = run_items_real(&engine, oracle, &self.cells, self.seed, &items)?;
            return Ok(self.collect(outs));
        }
        let exec = crate::exec::try_global()?;
        let n_pullers = pool.size().min(exec.workers()).max(1);
        let chunks = chunk_plan(items.len(), n_pullers);
        let cursor = AtomicUsize::new(0);
        // Early abort, like the serial `?` in run_real: once any chunk
        // errors, pullers stop claiming new chunks instead of burning
        // the rest of the grid's engine time.
        let failed = std::sync::atomic::AtomicBool::new(false);
        let per_puller: Vec<Vec<(usize, Result<Vec<QueryOutcome>>)>> = exec.scoped_map(
            "sweep:real",
            (0..n_pullers).collect::<Vec<usize>>(),
            |_, _puller| {
                let engine = pool.lease();
                let mut done = Vec::new();
                while !failed.load(Ordering::Relaxed) {
                    let c = cursor.fetch_add(1, Ordering::Relaxed);
                    let Some(range) = chunks.get(c) else { break };
                    let outs = run_items_real(
                        &engine,
                        oracle,
                        &self.cells,
                        self.seed,
                        &items[range.clone()],
                    );
                    if outs.is_err() {
                        failed.store(true, Ordering::Relaxed);
                    }
                    done.push((c, outs));
                }
                done
            },
        );
        // Re-establish plan order by chunk index before merging.  The
        // cursor hands indices out contiguously, so claimed chunks form
        // a prefix; unclaimed slots (possible only after an abort, i.e.
        // past the erroring chunk) are dropped — flatten surfaces the
        // first error in plan order before it could ever reach them.
        let mut by_chunk: Vec<Option<Result<Vec<QueryOutcome>>>> =
            (0..chunks.len()).map(|_| None).collect();
        for (c, outs) in per_puller.into_iter().flatten() {
            by_chunk[c] = Some(outs);
        }
        let results: Vec<Result<Vec<QueryOutcome>>> =
            by_chunk.into_iter().map_while(|slot| slot).collect();
        self.flatten(results)
    }

    /// Honor the bench env: simulator by default, real engines with
    /// `SPECREASON_BENCH_REAL=1` and a caller-provided engine pool.
    pub fn run_bench(&self, oracle: &Oracle, engines: Option<&EnginePool>) -> Result<Vec<CellResult>> {
        match engines {
            Some(pool) if bench_real() => self.run_real_pool(pool, oracle),
            _ => self.run_sim(oracle),
        }
    }

    /// Flatten per-chunk outcome runs back into plan order (first error
    /// in plan order wins) and fold into per-cell results.
    fn flatten(&self, results: Vec<Result<Vec<QueryOutcome>>>) -> Result<Vec<CellResult>> {
        let mut outs = Vec::with_capacity(self.len());
        for chunk in results {
            outs.extend(chunk?);
        }
        Ok(self.collect(outs))
    }

    /// Fold per-item outcomes (in plan order) into per-cell results.
    /// Aggregation borrows each outcome's metrics — nothing is cloned —
    /// and pushes them in exactly the sequential order, which is what
    /// makes the parallel path bit-identical to `run_sim_seq`.
    fn collect(&self, outs: Vec<QueryOutcome>) -> Vec<CellResult> {
        debug_assert_eq!(outs.len(), self.len());
        let per_cell = self.items_per_cell();
        let mut it = outs.into_iter();
        self.cells
            .iter()
            .map(|cell| {
                let outcomes: Vec<QueryOutcome> = it.by_ref().take(per_cell).collect();
                let mut agg = Aggregate::default();
                for o in &outcomes {
                    agg.push(&o.metrics);
                }
                CellResult { cell_label: label(cell), agg, outcomes }
            })
            .collect()
    }
}

/// Execute a run of work items on the simulator. Pure in (oracle, cells,
/// seed, items): every call with the same arguments produces the same
/// outcomes regardless of thread, which the determinism tests assert.
///
/// Queries come from the process-wide cross-cell cache
/// ([`qcache`](super::qcache)): cells sharing a `(dataset, seed)` reuse
/// one generated `Query` per index instead of regenerating it, with a
/// local one-entry memo so adjacent samples skip the cache lock;
/// `TraceGenerator::query` is pure, so this is purely a work saving, not
/// a behavior change.
fn run_items_sim(
    oracle: &Oracle,
    cells: &[Cell],
    seed: u64,
    items: &[WorkItem],
) -> Result<Vec<QueryOutcome>> {
    let mut outs = Vec::with_capacity(items.len());
    let mut cached: Option<(usize, usize, Arc<Query>)> = None;
    for item in items {
        let cell = &cells[item.cell_id];
        let stale = match &cached {
            Some((c, qi, _)) => *c != item.cell_id || *qi != item.query_idx,
            None => true,
        };
        if stale {
            let q = super::qcache::cached_query(cell.dataset, seed, item.query_idx);
            cached = Some((item.cell_id, item.query_idx, q));
        }
        let q: &Query = &cached.as_ref().expect("query cached").2;
        let clock = GpuClock::new(testbed_for(&cell.combo));
        let small_arch = arch_name(ModelClass::of(&cell.combo.small));
        let base_arch = arch_name(ModelClass::of(&cell.combo.base));
        let mut b = SimBackend::new(clock, small_arch, base_arch);
        outs.push(run_query(oracle, q, &cell.combo, &cell.cfg, &mut b, item.sample)?);
    }
    Ok(outs)
}

/// Execute a run of work items on one (leased) engine — the real-path
/// twin of [`run_items_sim`], shared by the serial reference and every
/// pool chunk.  Deterministic metrics depend only on (query seed,
/// sample), never on which engine ran the item.
fn run_items_real(
    engine: &Engine,
    oracle: &Oracle,
    cells: &[Cell],
    seed: u64,
    items: &[WorkItem],
) -> Result<Vec<QueryOutcome>> {
    let mut outs = Vec::with_capacity(items.len());
    let mut cached: Option<(usize, usize, Arc<Query>)> = None;
    for item in items {
        let cell = &cells[item.cell_id];
        let stale = match &cached {
            Some((c, qi, _)) => *c != item.cell_id || *qi != item.query_idx,
            None => true,
        };
        if stale {
            let q = super::qcache::cached_query(cell.dataset, seed, item.query_idx);
            cached = Some((item.cell_id, item.query_idx, q));
        }
        let q: &Query = &cached.as_ref().expect("query cached").2;
        let mut b = RealBackend::new(engine, &cell.combo.small, &cell.combo.base);
        let out = run_query(oracle, q, &cell.combo, &cell.cfg, &mut b, item.sample)?;
        b.release()?;
        outs.push(out);
    }
    Ok(outs)
}

/// Guided chunk plan over `total` items for `workers` workers: each
/// chunk takes `ceil(remaining / (2 * workers))` items (never fewer than
/// one), so chunks shrink geometrically toward per-item granularity at
/// the tail.  Pure in (total, workers) — chunk boundaries, and therefore
/// the merge, are independent of execution order.
pub fn chunk_plan(total: usize, workers: usize) -> Vec<Range<usize>> {
    let w = workers.max(1);
    let mut out = Vec::new();
    let mut start = 0usize;
    while start < total {
        let remaining = total - start;
        let len = remaining.div_ceil(2 * w).max(1);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Worker count for eval sweeps: `SPECREASON_BENCH_THREADS` if set
/// (validated — `0` is an error, not a silent fallback), else the
/// machine's available parallelism.  Exits with a clear message on an
/// invalid setting ([`crate::exec::or_exit`]); library callers wanting
/// a `Result` should use [`crate::exec::default_workers`].
pub fn bench_threads() -> usize {
    crate::exec::or_exit(crate::exec::default_workers())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{AcceptancePolicy, Combo, Scheme, SpecConfig};
    use crate::exec::{ExecConfig, PinPolicy, StealOrder};
    use crate::semantics::Dataset;

    fn grid() -> Sweep {
        let mut sw = Sweep::new(4, 2, 7);
        for ds in [Dataset::Aime, Dataset::Math500] {
            for scheme in [Scheme::SpecReason, Scheme::VanillaBase] {
                sw.cell(Cell {
                    dataset: ds,
                    scheme,
                    combo: Combo::new("qwq-sim", "r1-sim"),
                    cfg: SpecConfig {
                        scheme,
                        policy: AcceptancePolicy::Static { threshold: 7 },
                        ..Default::default()
                    },
                });
            }
        }
        sw
    }

    #[test]
    fn plan_is_cell_major_query_major_sample_minor() {
        let sw = grid();
        let plan = sw.plan();
        assert_eq!(plan.len(), 4 * 4 * 2);
        assert_eq!(plan[0], WorkItem { cell_id: 0, query_idx: 0, sample: 0 });
        assert_eq!(plan[1], WorkItem { cell_id: 0, query_idx: 0, sample: 1 });
        assert_eq!(plan[2], WorkItem { cell_id: 0, query_idx: 1, sample: 0 });
        assert_eq!(plan[8], WorkItem { cell_id: 1, query_idx: 0, sample: 0 });
        assert_eq!(
            plan[31],
            WorkItem { cell_id: 3, query_idx: 3, sample: 1 }
        );
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        let oracle = Oracle::default();
        let sw = grid();
        let seq = sw.run_sim_seq(&oracle).unwrap();
        let par = sw.run_sim_threads(&oracle, 3).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.cell_label, b.cell_label);
            assert_eq!(a.agg, b.agg, "{}: aggregate diverged", a.cell_label);
            assert_eq!(a.mean_gpu().to_bits(), b.mean_gpu().to_bits());
            for (oa, ob) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(oa.metrics.gpu_secs.to_bits(), ob.metrics.gpu_secs.to_bits());
                assert_eq!(oa.metrics.answer_correct, ob.metrics.answer_correct);
                assert_eq!(oa.metrics.steps_accepted, ob.metrics.steps_accepted);
            }
        }
    }

    #[test]
    fn adversarial_steal_order_is_bit_identical_too() {
        let oracle = Oracle::default();
        let sw = grid();
        let seq = sw.run_sim_seq(&oracle).unwrap();
        let exec = Executor::with_config(&ExecConfig {
            workers: Some(3),
            pin: PinPolicy::Floating,
            steal: StealOrder::Adversarial(0xDEC0DE),
        })
        .unwrap();
        let par = sw.run_sim_exec(&oracle, &exec).unwrap();
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.agg, b.agg, "{}: diverged under forced stealing", a.cell_label);
            assert_eq!(a.mean_gpu().to_bits(), b.mean_gpu().to_bits());
        }
    }

    #[test]
    fn empty_sweep_returns_no_results() {
        let oracle = Oracle::default();
        let sw = Sweep::new(4, 2, 7);
        assert!(sw.is_empty());
        assert!(sw.run_sim_threads(&oracle, 2).unwrap().is_empty());
        assert!(sw.run_sim_seq(&oracle).unwrap().is_empty());
    }

    #[test]
    fn chunk_plan_covers_all_items_in_order() {
        for (items, workers) in [(1usize, 4usize), (7, 4), (32, 1), (1920, 8), (3, 16), (0, 4)] {
            let plan = chunk_plan(items, workers);
            let mut next = 0usize;
            for r in &plan {
                assert_eq!(r.start, next, "chunks must tile contiguously");
                assert!(!r.is_empty());
                next = r.end;
            }
            assert_eq!(next, items, "chunks must cover every item exactly once");
        }
    }

    #[test]
    fn chunk_plan_shrinks_toward_the_tail() {
        let plan = chunk_plan(1920, 8);
        assert!(plan.len() > 8, "guided chunking yields more chunks than workers");
        let first = plan.first().unwrap().len();
        let last = plan.last().unwrap().len();
        assert!(first > last, "head chunks amortize, tail chunks balance");
        assert_eq!(last, 1, "the tail degenerates to per-item stealing");
        // Monotone non-increasing chunk sizes.
        for w in plan.windows(2) {
            assert!(w[0].len() >= w[1].len());
        }
    }

    #[test]
    fn bench_threads_is_positive() {
        assert!(bench_threads() >= 1);
    }
}
