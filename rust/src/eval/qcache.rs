//! Cross-cell query cache (ROADMAP item): sweep grids share one
//! generated [`Query`] per `(dataset, seed, index)` instead of every
//! cell regenerating the same `TraceGenerator` output.
//!
//! `TraceGenerator::query` is a pure function of `(dataset, seed,
//! index)`, so sharing is purely a startup-work saving — cached and
//! regenerated queries are identical, and sweep determinism is
//! unaffected.  Entries are `Arc`-shared and live for the process (grids
//! revisit the same small index ranges); [`clear`] exists for
//! long-running embedders that rotate workload seeds.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::semantics::{Dataset, Query, TraceGenerator};

type Cache = BTreeMap<(Dataset, u64), BTreeMap<usize, Arc<Query>>>;

static CACHE: OnceLock<Mutex<Cache>> = OnceLock::new();

fn cache() -> &'static Mutex<Cache> {
    CACHE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Fetch (or generate and cache) query `index` of `(dataset, seed)`.
pub fn cached_query(dataset: Dataset, seed: u64, index: usize) -> Arc<Query> {
    {
        let map = cache().lock().unwrap();
        if let Some(q) = map.get(&(dataset, seed)).and_then(|per| per.get(&index)) {
            return Arc::clone(q);
        }
    }
    // Generate outside the lock (the hot path on big grids is many
    // threads warming disjoint indices; duplicated generation on a race
    // is deterministic and harmless).
    let q = Arc::new(TraceGenerator::new(dataset, seed).query(index));
    let mut map = cache().lock().unwrap();
    let slot = map
        .entry((dataset, seed))
        .or_default()
        .entry(index)
        .or_insert_with(|| Arc::clone(&q));
    Arc::clone(slot)
}

/// Cached queries across all `(dataset, seed)` generations.
pub fn len() -> usize {
    cache().lock().unwrap().values().map(|per| per.len()).sum()
}

/// Drop every cached query (for embedders rotating workload seeds).
pub fn clear() {
    cache().lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_one_arc_per_key() {
        // A seed no other test uses, so the first call populates.
        let seed = 0xD15C_CA11u64;
        let a = cached_query(Dataset::Aime, seed, 3);
        let b = cached_query(Dataset::Aime, seed, 3);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        // Cached content is identical to a fresh generation.
        let fresh = TraceGenerator::new(Dataset::Aime, seed).query(3);
        assert_eq!(a.seed, fresh.seed);
        assert_eq!(a.prompt, fresh.prompt);
        assert_eq!(a.plan_len(), fresh.plan_len());
        // Distinct keys get distinct queries.
        let c = cached_query(Dataset::Aime, seed, 4);
        assert!(!Arc::ptr_eq(&a, &c));
        let d = cached_query(Dataset::Math500, seed, 3);
        assert_ne!(d.prompt, a.prompt);
    }
}
