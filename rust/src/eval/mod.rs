//! Evaluation harness: run (scheme × dataset × combo) cells and aggregate
//! pass@1 / latency / token statistics the way the paper reports them.
//!
//! Used by every `cargo bench` figure target, by `examples/paper_eval`,
//! and by the calibration self-checks.  Cells can run on the cost-model
//! simulator (fast, exact GPU clock) or the real PJRT engine (adds
//! measured wall-clock); both share [`coordinator::run_query`].

pub mod qcache;
pub mod sweep;

use anyhow::Result;

use crate::coordinator::{Combo, QueryOutcome, Scheme, SpecConfig};
use crate::engine::Engine;
use crate::exec::EnginePool;
use crate::metrics::{Aggregate, Testbed};
use crate::semantics::{Dataset, ModelClass, Oracle};

pub use sweep::{bench_threads, chunk_plan, Sweep, WorkItem};

/// One evaluation cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub dataset: Dataset,
    pub scheme: Scheme,
    pub combo: Combo,
    pub cfg: SpecConfig,
}

/// Aggregated result of a cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell_label: String,
    pub agg: Aggregate,
    pub outcomes: Vec<QueryOutcome>,
}

impl CellResult {
    /// Per-(query, sample) pass@1 flags in plan order — handy for
    /// determinism assertions.
    pub fn answer_flags(&self) -> Vec<bool> {
        self.outcomes.iter().map(|o| o.metrics.answer_correct).collect()
    }
    pub fn accuracy(&self) -> f64 {
        self.agg.accuracy()
    }
    pub fn mean_gpu(&self) -> f64 {
        self.agg.mean_gpu()
    }
    pub fn mean_wall(&self) -> f64 {
        self.agg.mean_wall()
    }
    pub fn mean_tokens(&self) -> f64 {
        self.agg.mean_thinking_tokens()
    }
    pub fn mean_offload(&self) -> f64 {
        self.agg.mean_offload_ratio()
    }
    pub fn mean_acceptance(&self) -> f64 {
        self.agg.mean_acceptance()
    }
}

/// Which testbed a combo's GPU clock should emulate (App. A.1 moves the
/// 70B combo to 4×A100).
pub fn testbed_for(combo: &Combo) -> Testbed {
    if ModelClass::of(&combo.base) == ModelClass::Large {
        Testbed::A100x4
    } else {
        Testbed::A6000x2
    }
}

pub(crate) fn arch_name(class: ModelClass) -> &'static str {
    match class {
        ModelClass::Small => "small",
        ModelClass::Base => "base",
        ModelClass::Large => "large",
    }
}

/// Run a cell on the simulator: `n_queries` queries × `samples` pass@1
/// samples each.  Routed through the parallel sweep engine (thread count
/// from `SPECREASON_BENCH_THREADS`, default = available parallelism);
/// results are bit-identical to a sequential run — see [`sweep`].
pub fn run_cell_sim(
    oracle: &Oracle,
    cell: &Cell,
    n_queries: usize,
    samples: usize,
    seed: u64,
) -> Result<CellResult> {
    let mut sw = Sweep::new(n_queries, samples, seed);
    sw.cell(cell.clone());
    Ok(sw.run_sim(oracle)?.remove(0))
}

/// Run a cell on the real engine (the engine must have the combo's models
/// loaded).  Items execute sequentially — the engine serializes the two
/// colocated models — via the same sweep planner/merge code.
pub fn run_cell_real(
    engine: &Engine,
    oracle: &Oracle,
    cell: &Cell,
    n_queries: usize,
    samples: usize,
    seed: u64,
) -> Result<CellResult> {
    let mut sw = Sweep::new(n_queries, samples, seed);
    sw.cell(cell.clone());
    Ok(sw.run_real(engine, oracle)?.remove(0))
}

pub(crate) fn label(cell: &Cell) -> String {
    format!(
        "{}/{}/{}",
        cell.dataset.name(),
        cell.combo.label(),
        cell.scheme.name()
    )
}

/// Bench-environment knobs shared by the `cargo bench` figure targets.
/// `SPECREASON_BENCH_QUERIES` / `SPECREASON_BENCH_SAMPLES` trade time for
/// tightness; `SPECREASON_BENCH_REAL=1` runs cells on the PJRT engine
/// instead of the calibrated simulator.
pub fn bench_queries() -> usize {
    std::env::var("SPECREASON_BENCH_QUERIES").ok().and_then(|v| v.parse().ok()).unwrap_or(16)
}
pub fn bench_samples() -> usize {
    std::env::var("SPECREASON_BENCH_SAMPLES").ok().and_then(|v| v.parse().ok()).unwrap_or(2)
}
pub fn bench_real() -> bool {
    std::env::var("SPECREASON_BENCH_REAL").map(|v| v == "1").unwrap_or(false)
}

/// Explicit engine-count override for real-path sweeps
/// (`SPECREASON_BENCH_ENGINES`, the per-engine-memory cap), if set.
/// [`crate::exec::env_positive`] semantics: an invalid value (0 or
/// garbage) is an `Err`, not a silent fallback — a typo'd memory cap
/// must not quietly load one engine per core.  Binary entry points
/// surface the error via [`crate::exec::or_exit`].
pub fn env_engines() -> Result<Option<usize>> {
    crate::exec::env_positive("SPECREASON_BENCH_ENGINES", "one engine per sweep worker")
}

/// Engine count for a real-path (`SPECREASON_BENCH_REAL=1`) sweep: one
/// engine per worker, never more than the work items (extra engines
/// could never be leased; each carries a full KV partition) nor the
/// `SPECREASON_BENCH_ENGINES` memory cap.  The single home of the
/// capping policy — `specreason run` and the fig benches both call it.
pub fn engine_count(threads: usize, work_items: usize) -> Result<usize> {
    Ok(threads
        .min(work_items.max(1))
        .min(env_engines()?.unwrap_or(usize::MAX)))
}

/// Run a cell honoring the bench env (sim by default, real with
/// SPECREASON_BENCH_REAL=1 and a caller-provided engine pool).
pub fn run_cell_bench(
    oracle: &Oracle,
    cell: &Cell,
    engines: Option<&EnginePool>,
    seed: u64,
) -> Result<CellResult> {
    let mut sw = Sweep::new(bench_queries(), bench_samples(), seed);
    sw.cell(cell.clone());
    Ok(sw.run_bench(oracle, engines)?.remove(0))
}

/// The four main-results model combinations (§5.1).
pub fn main_combos() -> Vec<Combo> {
    vec![
        Combo::new("qwq-sim", "r1-sim"),
        Combo::new("qwq-sim", "zr1-sim"),
        Combo::new("skywork-sim", "r1-sim"),
        Combo::new("skywork-sim", "zr1-sim"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_cell_runs_and_aggregates() {
        let oracle = Oracle::default();
        let cell = Cell {
            dataset: Dataset::Math500,
            scheme: Scheme::SpecReason,
            combo: Combo::new("qwq-sim", "r1-sim"),
            cfg: SpecConfig::default(),
        };
        let r = run_cell_sim(&oracle, &cell, 10, 2, 1).unwrap();
        assert_eq!(r.agg.n(), 20);
        assert!(r.mean_gpu() > 0.0);
        assert!((0.0..=1.0).contains(&r.accuracy()));
        assert!(r.cell_label.contains("math500"));
    }

    #[test]
    fn testbed_routing() {
        assert_eq!(testbed_for(&Combo::new("qwq-sim", "r1-sim")), Testbed::A6000x2);
        assert_eq!(testbed_for(&Combo::new("r1-70b-sim", "r1-sim")), Testbed::A100x4);
    }
}
