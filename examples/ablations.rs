//! Design-choice ablations (DESIGN.md §9): the knobs the paper fixes are
//! swept here to show the system is not tuned to a knife's edge.
//!
//!   cargo run --release --example ablations
//!
//! * draft length k for token-level speculative decoding (paper: 5);
//! * verification-template length (paper: ~70 tokens);
//! * answer-token allowance;
//! all on the calibrated GPU clock (decision parity with the real
//! engine is covered by integration tests).

use anyhow::Result;

use specreason::coordinator::{run_query, Combo, Scheme, SimBackend, SpecConfig};
use specreason::eval::testbed_for;
use specreason::metrics::{Aggregate, GpuClock};
use specreason::semantics::{Dataset, Oracle, TraceGenerator};
use specreason::util::bench::Table;

fn run_cell(
    oracle: &Oracle,
    combo: &Combo,
    ds: Dataset,
    cfg: &SpecConfig,
    n_queries: usize,
    samples: usize,
) -> Result<Aggregate> {
    let clock = GpuClock::new(testbed_for(combo));
    let gen = TraceGenerator::new(ds, 1234);
    let mut agg = Aggregate::default();
    for q in gen.queries(n_queries) {
        for s in 0..samples {
            let mut b = SimBackend::new(clock, "small", "base");
            agg.push(run_query(oracle, &q, combo, cfg, &mut b, s)?.metrics);
        }
    }
    Ok(agg)
}

fn main() -> Result<()> {
    let oracle = Oracle::default();
    let combo = Combo::new("qwq-sim", "r1-sim");
    let (n, s) = (32, 3);

    // ---- draft length k (SpecDecode) ----
    let mut t = Table::new(
        "ablation: draft length k (spec-decode, aime, GPU clock)",
        &["k", "latency (s)", "draft acceptance", "tokens/round"],
    );
    for k in [2usize, 3, 5, 8, 12] {
        let cfg = SpecConfig { scheme: Scheme::SpecDecode, draft_k: k, ..Default::default() };
        let agg = run_cell(&oracle, &combo, Dataset::Aime, &cfg, n, s)?;
        let acc_rate: f64 = agg.queries.iter().map(|q| q.draft_acceptance_rate()).sum::<f64>()
            / agg.n() as f64;
        t.row(vec![
            k.to_string(),
            format!("{:.1}", agg.mean_gpu()),
            format!("{:.2}", acc_rate),
            format!("{:.1}", acc_rate * k as f64 + 1.0),
        ]);
    }
    t.print();
    println!("(longer drafts waste more rejected work; k=5 sits near the paper's sweet spot)");

    // ---- verification template length ----
    let mut t = Table::new(
        "ablation: verify-template length (spec-reason, aime, GPU clock)",
        &["template tokens", "latency (s)", "verify share of gpu time"],
    );
    for tl in [16usize, 40, 70, 128, 256] {
        let cfg = SpecConfig { verify_template_len: tl, ..Default::default() };
        let agg = run_cell(&oracle, &combo, Dataset::Aime, &cfg, n, s)?;
        let verify: f64 = agg.queries.iter()
            .map(|q| q.phase_gpu.get("verify").copied().unwrap_or(0.0))
            .sum::<f64>() / agg.n() as f64;
        t.row(vec![
            tl.to_string(),
            format!("{:.1}", agg.mean_gpu()),
            format!("{:.1}%", 100.0 * verify / agg.mean_gpu()),
        ]);
    }
    t.print();
    println!("(§4.1: short templates keep verification ≈ 1–2 decode tokens; even 256\n tokens only grows the verify share modestly thanks to prefix reuse)");

    // ---- answer-token allowance ----
    let mut t = Table::new(
        "ablation: answer-token allowance (spec-reason, math500)",
        &["answer tokens", "latency (s)", "pass@1"],
    );
    for at in [8usize, 24, 64] {
        let cfg = SpecConfig { answer_tokens: at, ..Default::default() };
        let agg = run_cell(&oracle, &combo, Dataset::Math500, &cfg, n, s)?;
        t.row(vec![
            at.to_string(),
            format!("{:.1}", agg.mean_gpu()),
            format!("{:.3}", agg.accuracy()),
        ]);
    }
    t.print();
    println!("(answer length is pure latency: correctness is fixed by the thinking phase)");
    Ok(())
}
