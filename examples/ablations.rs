//! Design-choice ablations (DESIGN.md §9): the knobs the paper fixes are
//! swept here to show the system is not tuned to a knife's edge.
//!
//!   cargo run --release --example ablations
//!
//! * draft length k for token-level speculative decoding (paper: 5);
//! * verification-template length (paper: ~70 tokens);
//! * answer-token allowance;
//! all on the calibrated GPU clock (decision parity with the real
//! engine is covered by integration tests).

use anyhow::Result;

use specreason::coordinator::{Combo, Scheme, SpecConfig};
use specreason::eval::{run_cell_sim, Cell};
use specreason::metrics::Aggregate;
use specreason::semantics::{Dataset, Oracle};
use specreason::util::bench::Table;

fn run_cell(
    oracle: &Oracle,
    combo: &Combo,
    ds: Dataset,
    cfg: &SpecConfig,
    n_queries: usize,
    samples: usize,
) -> Result<Aggregate> {
    // Routed through the parallel sweep engine (eval::sweep).
    let cell = Cell { dataset: ds, scheme: cfg.scheme, combo: combo.clone(), cfg: cfg.clone() };
    Ok(run_cell_sim(oracle, &cell, n_queries, samples, 1234)?.agg)
}

fn main() -> Result<()> {
    let oracle = Oracle::default();
    let combo = Combo::new("qwq-sim", "r1-sim");
    let (n, s) = (32, 3);

    // ---- draft length k (SpecDecode) ----
    let mut t = Table::new(
        "ablation: draft length k (spec-decode, aime, GPU clock)",
        &["k", "latency (s)", "draft acceptance", "tokens/round"],
    );
    for k in [2usize, 3, 5, 8, 12] {
        let cfg = SpecConfig { scheme: Scheme::SpecDecode, draft_k: k, ..Default::default() };
        let agg = run_cell(&oracle, &combo, Dataset::Aime, &cfg, n, s)?;
        let acc_rate = agg.mean_draft_acceptance();
        t.row(vec![
            k.to_string(),
            format!("{:.1}", agg.mean_gpu()),
            format!("{:.2}", acc_rate),
            format!("{:.1}", acc_rate * k as f64 + 1.0),
        ]);
    }
    t.print();
    println!("(longer drafts waste more rejected work; k=5 sits near the paper's sweet spot)");

    // ---- verification template length ----
    let mut t = Table::new(
        "ablation: verify-template length (spec-reason, aime, GPU clock)",
        &["template tokens", "latency (s)", "verify share of gpu time"],
    );
    for tl in [16usize, 40, 70, 128, 256] {
        let cfg = SpecConfig { verify_template_len: tl, ..Default::default() };
        let agg = run_cell(&oracle, &combo, Dataset::Aime, &cfg, n, s)?;
        let verify = agg.mean_phase_gpu("verify");
        t.row(vec![
            tl.to_string(),
            format!("{:.1}", agg.mean_gpu()),
            format!("{:.1}%", 100.0 * verify / agg.mean_gpu()),
        ]);
    }
    t.print();
    println!("(§4.1: short templates keep verification ≈ 1–2 decode tokens; even 256\n tokens only grows the verify share modestly thanks to prefix reuse)");

    // ---- answer-token allowance ----
    let mut t = Table::new(
        "ablation: answer-token allowance (spec-reason, math500)",
        &["answer tokens", "latency (s)", "pass@1"],
    );
    for at in [8usize, 24, 64] {
        let cfg = SpecConfig { answer_tokens: at, ..Default::default() };
        let agg = run_cell(&oracle, &combo, Dataset::Math500, &cfg, n, s)?;
        t.row(vec![
            at.to_string(),
            format!("{:.1}", agg.mean_gpu()),
            format!("{:.3}", agg.accuracy()),
        ]);
    }
    t.print();
    println!("(answer length is pure latency: correctness is fixed by the thinking phase)");
    Ok(())
}
