//! Quickstart: load the two-model stack and run one query with
//! SpecReason vs vanilla base-model inference.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Shows the basic public API: Engine -> RealBackend -> run_query.

use anyhow::Result;

use specreason::coordinator::{run_query, Combo, RealBackend, Scheme, SpecConfig};
use specreason::engine::{Engine, EngineConfig};
use specreason::semantics::{Dataset, Oracle, TraceGenerator};

fn main() -> Result<()> {
    // 1. Load the serving engine: base LRM proxy + small speculator,
    //    colocated with a statically partitioned KV cache (paper §4.1).
    println!("loading engine (compiling AOT artifacts)...");
    let engine = Engine::new(&EngineConfig {
        models: vec!["qwq-sim".into(), "r1-sim".into()],
        ..Default::default()
    })?;
    println!(
        "engine up on PJRT '{}': models {:?}",
        engine.device.platform(),
        engine.model_names()
    );

    // 2. A workload: one AIME-profile query (synthetic trace; DESIGN.md §3).
    let oracle = Oracle::default();
    let query = TraceGenerator::new(Dataset::Math500, 42).query(0);
    println!(
        "\nquery: dataset=math500 difficulty={:.2} plan={} steps prompt={} tokens",
        query.difficulty,
        query.plan_len(),
        query.prompt.len()
    );

    let combo = Combo::new("qwq-sim", "r1-sim");
    // Keep the budget small so the demo finishes in ~a minute of CPU time.
    let budget = 192;

    // 3. Vanilla base-model inference (the latency baseline).
    let cfg = SpecConfig { scheme: Scheme::VanillaBase, token_budget: budget, ..Default::default() };
    let mut backend = RealBackend::new(&engine, "r1-sim", "qwq-sim");
    let vanilla = run_query(&oracle, &query, &combo, &cfg, &mut backend, 0)?;
    backend.release()?;

    // 4. SpecReason: small model speculates steps, base model verifies.
    let cfg = SpecConfig { scheme: Scheme::SpecReason, token_budget: budget, ..Default::default() };
    let mut backend = RealBackend::new(&engine, "r1-sim", "qwq-sim");
    let spec = run_query(&oracle, &query, &combo, &cfg, &mut backend, 0)?;
    backend.release()?;

    // 5. Compare.
    println!("\n{:<22} {:>10} {:>10} {:>8} {:>9}", "scheme", "wall (s)", "gpu (s)", "tokens", "accepted");
    for (name, out) in [("vanilla-base", &vanilla), ("spec-reason", &spec)] {
        println!(
            "{:<22} {:>10.2} {:>10.2} {:>8} {:>6}/{}",
            name,
            out.metrics.wall_secs,
            out.metrics.gpu_secs,
            out.metrics.thinking_tokens,
            out.metrics.steps_accepted,
            out.metrics.steps_total,
        );
    }
    println!(
        "\nspeedup (gpu clock): {:.2}x   speedup (wall): {:.2}x",
        vanilla.metrics.gpu_secs / spec.metrics.gpu_secs,
        vanilla.metrics.wall_secs / spec.metrics.wall_secs,
    );
    println!(
        "verify scores given by the base model: {:?}",
        spec.metrics.verify_scores
    );
    Ok(())
}
