//! paper_eval — regenerate every figure of the paper's evaluation.
//!
//!     cargo run --release --example paper_eval -- --fig 3 --sim
//!     cargo run --release --example paper_eval -- --fig all --sim
//!     cargo run --release --example paper_eval -- --fig 5            # real engine
//!
//! `--sim` uses the calibrated GPU-clock simulator (fast, exact same
//! decisions as the real path — parity-tested); without it the cells run
//! on the real PJRT engine and additionally report measured wall-clock.
//! `--queries/--samples` trade time for tightness (paper: k=16 samples).
//!
//! The printed tables correspond 1:1 to the paper's figures; the
//! paper-vs-measured comparison lives in EXPERIMENTS.md.

use anyhow::Result;

use specreason::coordinator::{AcceptancePolicy, Combo, Scheme, SpecConfig};
use specreason::engine::{Engine, EngineConfig};
use specreason::eval::{bench_threads, main_combos, run_cell_real, Cell, CellResult, Sweep};
use specreason::semantics::{Dataset, Oracle, TraceGenerator};
use specreason::util::bench::Table;
use specreason::util::cli::Command;
use specreason::util::stats::{pearson, Histogram};

struct Ctx {
    oracle: Oracle,
    sim: bool,
    queries: usize,
    samples: usize,
    seed: u64,
    engines: std::cell::RefCell<std::collections::BTreeMap<String, std::rc::Rc<Engine>>>,
}

impl Ctx {
    fn engine_for(&self, combo: &Combo) -> Result<std::rc::Rc<Engine>> {
        let key = combo.label();
        if let Some(e) = self.engines.borrow().get(&key) {
            return Ok(e.clone());
        }
        eprintln!("[engine] loading {key}...");
        let e = std::rc::Rc::new(Engine::new(&EngineConfig {
            models: vec![combo.base.clone(), combo.small.clone()],
            testbed: specreason::eval::testbed_for(combo),
            ..Default::default()
        })?);
        self.engines.borrow_mut().insert(key, e.clone());
        Ok(e)
    }

    /// Run a batch of cells. In sim mode the whole batch is planned as
    /// one sweep and fanned out across the shared thread pool (results
    /// are bit-identical to sequential execution); on the real engine the
    /// cells run sequentially, each against its combo's engine.
    fn run_cells(&self, cells: Vec<Cell>) -> Result<Vec<CellResult>> {
        if self.sim {
            let mut sweep = Sweep::new(self.queries, self.samples, self.seed);
            for cell in cells {
                sweep.cell(cell);
            }
            eprintln!(
                "[sweep] {} cells / {} work items on {} threads",
                sweep.cells().len(),
                sweep.len(),
                bench_threads()
            );
            sweep.run_sim(&self.oracle)
        } else {
            cells
                .iter()
                .map(|cell| {
                    let engine = self.engine_for(&cell.combo)?;
                    run_cell_real(&engine, &self.oracle, cell, self.queries, self.samples, self.seed)
                })
                .collect()
        }
    }
}

fn cfg_for(scheme: Scheme, threshold: u8) -> SpecConfig {
    SpecConfig {
        scheme,
        policy: AcceptancePolicy::Static { threshold },
        ..Default::default()
    }
}

fn main() -> Result<()> {
    let cmd = Command::new("paper_eval", "regenerate the paper's figures")
        .opt("fig", "3|4|5|6|7|8|9|all", Some("all"))
        .opt("queries", "queries per cell", Some("24"))
        .opt("samples", "pass@1 samples per query (paper: 16)", Some("4"))
        .opt("seed", "workload seed", Some("1234"))
        .flag("sim", "run on the calibrated simulator (fast)");
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = cmd.parse(&raw)?;
    let ctx = Ctx {
        oracle: Oracle::default(),
        sim: args.flag("sim"),
        queries: args.usize("queries", 24)?,
        samples: args.usize("samples", 4)?,
        seed: args.u64("seed", 1234)?,
        engines: Default::default(),
    };
    let fig = args.get_or("fig", "all").to_string();
    let want = |f: &str| fig == "all" || fig == f;

    if want("3") {
        fig3(&ctx)?;
    }
    if want("4") {
        fig4(&ctx)?;
    }
    if want("5") {
        fig5(&ctx)?;
    }
    if want("6") {
        fig6(&ctx)?;
    }
    if want("7") {
        fig7(&ctx)?;
    }
    if want("8") {
        fig8(&ctx)?;
    }
    if want("9") {
        fig9(&ctx)?;
    }
    Ok(())
}

/// Fig. 3: accuracy & latency, 5 schemes × 3 datasets × 4 combos, plus
/// the §5.2 text statistics (acceptance ranges, +Decode-vs-Decode cuts).
fn fig3(ctx: &Ctx) -> Result<()> {
    for combo in main_combos() {
        let mut cells = Vec::new();
        for ds in Dataset::all() {
            for scheme in Scheme::all() {
                cells.push(Cell {
                    dataset: ds,
                    scheme,
                    combo: combo.clone(),
                    cfg: cfg_for(scheme, 7),
                });
            }
        }
        let results = ctx.run_cells(cells)?;
        let mut idx = 0;
        let mut t = Table::new(
            &format!("Fig. 3 — {} (latency = calibrated GPU clock)", combo.label()),
            &["dataset", "scheme", "pass@1", "latency (s)", "speedup", "offload", "wall (s)"],
        );
        for ds in Dataset::all() {
            let mut base_latency = None;
            for scheme in Scheme::all() {
                let r = &results[idx];
                idx += 1;
                // Guard the idx bookkeeping against build/read loop drift.
                assert_eq!(r.cell_label, format!("{}/{}/{}", ds.name(), combo.label(), scheme.name()));
                let lat = r.mean_gpu();
                if scheme == Scheme::VanillaBase {
                    base_latency = Some(lat);
                }
                let speedup = base_latency
                    .map(|b| format!("{:.2}x", b / lat))
                    .unwrap_or_default();
                t.row(vec![
                    ds.name().into(),
                    scheme.name().into(),
                    format!("{:.3}", r.accuracy()),
                    format!("{:.1}", lat),
                    speedup,
                    format!("{:.2}", r.mean_offload()),
                    format!("{:.1}", r.mean_wall()),
                ]);
            }
        }
        t.print();
    }
    Ok(())
}

/// Fig. 4a: thinking-token counts; Fig. 4b: accuracy gap vs token budget
/// (QwQ + Zyphra combo, AIME for 4b — §5.2).
fn fig4(ctx: &Ctx) -> Result<()> {
    let combo = Combo::new("qwq-sim", "zr1-sim");
    // Both panels planned as one batch.
    let mut cells = Vec::new();
    for ds in Dataset::all() {
        for scheme in [Scheme::VanillaBase, Scheme::VanillaSmall, Scheme::SpecReason] {
            cells.push(Cell { dataset: ds, scheme, combo: combo.clone(), cfg: cfg_for(scheme, 7) });
        }
    }
    let budgets = [192usize, 320, 448, 576, 704];
    for &budget in &budgets {
        for scheme in [Scheme::VanillaBase, Scheme::SpecReason] {
            let mut cfg = cfg_for(scheme, 7);
            cfg.token_budget = budget;
            cells.push(Cell { dataset: Dataset::Aime, scheme, combo: combo.clone(), cfg });
        }
    }
    let results = ctx.run_cells(cells)?;

    let mut t = Table::new(
        "Fig. 4a — thinking-token counts (qwq-sim + zr1-sim)",
        &["dataset", "base tokens", "small tokens", "specreason tokens", "reduction"],
    );
    let mut idx = 0;
    for ds in Dataset::all() {
        let (base, small, spec) = (&results[idx], &results[idx + 1], &results[idx + 2]);
        idx += 3;
        // Guard the idx bookkeeping against build/read loop drift.
        assert_eq!(base.cell_label, format!("{}/{}/vanilla-base", ds.name(), combo.label()));
        t.row(vec![
            ds.name().into(),
            format!("{:.0}", base.mean_tokens()),
            format!("{:.0}", small.mean_tokens()),
            format!("{:.0}", spec.mean_tokens()),
            format!("{:.2}x", base.mean_tokens() / spec.mean_tokens()),
        ]);
    }
    t.print();

    let mut t = Table::new(
        "Fig. 4b — [AIME] accuracy vs thinking-token budget (qwq-sim + zr1-sim)",
        &["budget", "base pass@1", "specreason pass@1", "gap"],
    );
    for &budget in &budgets {
        let (base, spec) = (&results[idx], &results[idx + 1]);
        idx += 2;
        assert_eq!(base.cell_label, format!("aime/{}/vanilla-base", combo.label()));
        assert_eq!(spec.cell_label, format!("aime/{}/spec-reason", combo.label()));
        t.row(vec![
            budget.to_string(),
            format!("{:.3}", base.accuracy()),
            format!("{:.3}", spec.accuracy()),
            format!("{:+.1}%", 100.0 * (spec.accuracy() - base.accuracy())),
        ]);
    }
    t.print();
    Ok(())
}

/// Fig. 5: the acceptance-threshold knob (QwQ + R1-1.5B, §5.3).
fn fig5(ctx: &Ctx) -> Result<()> {
    let combo = Combo::new("qwq-sim", "r1-sim");
    let thresholds = [3u8, 5, 7, 9];
    let schemes = [Scheme::SpecReason, Scheme::SpecReasonPlusDecode];
    let mut cells = Vec::new();
    for ds in Dataset::all() {
        for &threshold in &thresholds {
            for scheme in schemes {
                cells.push(Cell {
                    dataset: ds,
                    scheme,
                    combo: combo.clone(),
                    cfg: cfg_for(scheme, threshold),
                });
            }
        }
    }
    let results = ctx.run_cells(cells)?;
    let mut idx = 0;
    for ds in Dataset::all() {
        let mut t = Table::new(
            &format!("Fig. 5 — [{}] threshold sweep (qwq-sim + r1-sim)", ds.name()),
            &["threshold", "scheme", "pass@1", "latency (s)", "acceptance"],
        );
        for &threshold in &thresholds {
            for scheme in schemes {
                let r = &results[idx];
                idx += 1;
                assert_eq!(r.cell_label, format!("{}/{}/{}", ds.name(), combo.label(), scheme.name()));
                t.row(vec![
                    threshold.to_string(),
                    scheme.name().into(),
                    format!("{:.3}", r.accuracy()),
                    format!("{:.1}", r.mean_gpu()),
                    format!("{:.2}", r.mean_acceptance()),
                ]);
            }
        }
        t.print();
    }
    Ok(())
}

/// Fig. 6: forcing the first n steps onto the base model (AIME, §5.3).
fn fig6(ctx: &Ctx) -> Result<()> {
    let combo = Combo::new("qwq-sim", "r1-sim");
    let mut t = Table::new(
        "Fig. 6 — [AIME] first-n-base knob (qwq-sim + r1-sim)",
        &["first n", "pass@1", "latency (s)", "offload"],
    );
    let ns = [0usize, 4, 8, 12, 16];
    let cells = ns
        .iter()
        .map(|&n| {
            let mut cfg = cfg_for(Scheme::SpecReason, 7);
            cfg.first_n_base = n;
            Cell { dataset: Dataset::Aime, scheme: Scheme::SpecReason, combo: combo.clone(), cfg }
        })
        .collect();
    let results = ctx.run_cells(cells)?;
    for (n, r) in ns.iter().zip(&results) {
        t.row(vec![
            n.to_string(),
            format!("{:.3}", r.accuracy()),
            format!("{:.1}", r.mean_gpu()),
            format!("{:.2}", r.mean_offload()),
        ]);
    }
    t.print();
    println!("(paper sweeps n in 0..40 on ~30-step plans at budget 8192; ours scale to ~24-step plans)");
    Ok(())
}

/// Fig. 7: base-model utility score vs PRM score, ten bins (§5.4).
fn fig7(ctx: &Ctx) -> Result<()> {
    let oracle = &ctx.oracle;
    let gen = TraceGenerator::new(Dataset::Aime, ctx.seed);
    let mut hist = Histogram::new(0.0, 1.0, 10);
    let mut prm_scores = Vec::new();
    let mut util_scores = Vec::new();
    for q in gen.queries(ctx.queries.max(30)) {
        for step in 0..q.plan_len() {
            let quality = oracle.step_quality(&q, step, 0, "r1-sim");
            let prm = oracle.prm_score(&q, step, 0, quality);
            let util = oracle.verifier_score(&q, step, 0, quality, "qwq-sim");
            hist.record(prm, util as f64);
            prm_scores.push(prm);
            util_scores.push(util as f64);
        }
    }
    let mut t = Table::new(
        "Fig. 7 — base-model utility score vs PRM score (AIME, r1-sim steps)",
        &["PRM bin", "n steps", "mean utility score"],
    );
    for b in 0..hist.bins() {
        let (lo, hi) = hist.bin_bounds(b);
        t.row(vec![
            format!("[{lo:.1}, {hi:.1})"),
            hist.count(b).to_string(),
            hist.bin_mean(b).map(|m| format!("{m:.2}")).unwrap_or("-".into()),
        ]);
    }
    t.print();
    println!("pearson r = {:.3}", pearson(&prm_scores, &util_scores));
    Ok(())
}

/// Fig. 8: the R1-70B base model on the A100 testbed (App. A.1).
fn fig8(ctx: &Ctx) -> Result<()> {
    let combo = Combo::new("r1-70b-sim", "r1-sim");
    let mut t = Table::new(
        "Fig. 8 — [AIME] r1-70b-sim + r1-sim on the 4xA100 clock (App. A.1)",
        &["threshold", "scheme", "pass@1", "latency (s)", "offload"],
    );
    // §A.1: a stricter threshold (8) preserves accuracy with the weaker
    // judge; compare against vanilla.  One batch: vanilla + the ladder.
    let thresholds = [5u8, 7, 8, 9];
    let mut cells = vec![Cell {
        dataset: Dataset::Aime,
        scheme: Scheme::VanillaBase,
        combo: combo.clone(),
        cfg: cfg_for(Scheme::VanillaBase, 8),
    }];
    for &threshold in &thresholds {
        cells.push(Cell {
            dataset: Dataset::Aime,
            scheme: Scheme::SpecReason,
            combo: combo.clone(),
            cfg: cfg_for(Scheme::SpecReason, threshold),
        });
    }
    let results = ctx.run_cells(cells)?;
    let base = &results[0];
    t.row(vec![
        "-".into(),
        "vanilla-base".into(),
        format!("{:.3}", base.accuracy()),
        format!("{:.1}", base.mean_gpu()),
        "0.00".into(),
    ]);
    for (threshold, r) in thresholds.iter().zip(&results[1..]) {
        t.row(vec![
            threshold.to_string(),
            "spec-reason".into(),
            format!("{:.3}", r.accuracy()),
            format!("{:.1}", r.mean_gpu()),
            format!("{:.2}", r.mean_offload()),
        ]);
    }
    t.print();
    println!("(expect a smaller speedup than Fig. 3: the 70B/1.5B TPT gap is narrower on A100s\n and the weaker judge needs a stricter threshold — §A.1)");
    Ok(())
}

/// Fig. 9: token counts across all datasets × combos (App. A.2).
fn fig9(ctx: &Ctx) -> Result<()> {
    let mut t = Table::new(
        "Fig. 9 — thinking-token counts, all datasets x combos",
        &["combo", "dataset", "base", "small", "specreason", "reduction"],
    );
    let mut cells = Vec::new();
    for combo in main_combos() {
        for ds in Dataset::all() {
            for scheme in [Scheme::VanillaBase, Scheme::VanillaSmall, Scheme::SpecReason] {
                cells.push(Cell { dataset: ds, scheme, combo: combo.clone(), cfg: cfg_for(scheme, 7) });
            }
        }
    }
    let results = ctx.run_cells(cells)?;
    let mut idx = 0;
    for combo in main_combos() {
        for ds in Dataset::all() {
            let (base, small, spec) = (&results[idx], &results[idx + 1], &results[idx + 2]);
            idx += 3;
            // Guard the idx bookkeeping against build/read loop drift.
            assert_eq!(base.cell_label, format!("{}/{}/vanilla-base", ds.name(), combo.label()));
            t.row(vec![
                combo.label(),
                ds.name().into(),
                format!("{:.0}", base.mean_tokens()),
                format!("{:.0}", small.mean_tokens()),
                format!("{:.0}", spec.mean_tokens()),
                format!("{:.2}x", base.mean_tokens() / spec.mean_tokens()),
            ]);
        }
    }
    t.print();
    Ok(())
}
