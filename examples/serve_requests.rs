//! End-to-end serving driver (the required E2E validation example):
//! boots the full TCP serving stack — router, bounded admission queue,
//! engine worker running real PJRT compute — then drives it with a
//! multi-client workload of batched requests and reports
//! latency/throughput percentiles per scheme.  Afterwards it
//! demonstrates the v2 streaming protocol: one query watched step by
//! step through the typed `StreamClient`, and one long query cancelled
//! mid-flight.
//!
//!     make artifacts && cargo run --release --example serve_requests
//!
//! Options (env): SPECREASON_E2E_REQUESTS (default 12),
//! SPECREASON_E2E_CLIENTS (default 3), SPECREASON_E2E_BUDGET (default 128).
//! Results of a full run are recorded in EXPERIMENTS.md §E2E.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::Result;

use specreason::config::DeployConfig;
use specreason::server::{Client, Server, StreamClient, WireEvent};
use specreason::util::bench::Table;
use specreason::util::json::Json;
use specreason::util::stats::Sample;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let n_requests = env_usize("SPECREASON_E2E_REQUESTS", 12);
    let n_clients = env_usize("SPECREASON_E2E_CLIENTS", 3);
    let budget = env_usize("SPECREASON_E2E_BUDGET", 128);

    // --- boot the full stack on an ephemeral port ---
    println!("booting serving stack (loading + compiling artifacts)...");
    let cfg = DeployConfig {
        addr: "127.0.0.1:0".into(),
        token_budget: budget,
        answer_tokens: 8,
        ..Default::default()
    };
    let t0 = Instant::now();
    let server = Server::bind(cfg)?;
    let addr = server.addr.to_string();
    println!("server up on {addr} in {:.1}s", t0.elapsed().as_secs_f64());
    let server_thread = thread::spawn(move || server.run().unwrap());

    let mut table = Table::new(
        &format!("end-to-end serving: {n_requests} requests × {n_clients} clients, budget {budget}"),
        &["scheme", "p50 (s)", "p95 (s)", "mean (s)", "throughput (req/s)", "accuracy"],
    );

    for scheme in ["vanilla-base", "spec-reason", "spec-reason+decode"] {
        let started = Instant::now();
        let (tx, rx) = mpsc::channel::<(f64, bool)>();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let addr = addr.clone();
            let tx = tx.clone();
            let scheme = scheme.to_string();
            handles.push(thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                // Stripe the request ids round-robin across clients.
                let mut i = c;
                while i < n_requests {
                    let t = Instant::now();
                    let r = client
                        .call(Json::obj(vec![
                            ("op", Json::str("query")),
                            ("dataset", Json::str("math500")),
                            ("query_index", Json::num(i as f64)),
                            ("scheme", Json::str(scheme.as_str())),
                            ("sample", Json::num(0.0)),
                        ]))
                        .expect("query");
                    let correct = r.get("correct").as_bool().unwrap_or(false);
                    tx.send((t.elapsed().as_secs_f64(), correct)).unwrap();
                    i += n_clients;
                }
            }));
        }
        drop(tx);
        let mut latencies = Sample::new();
        let mut correct = 0usize;
        let mut served = 0usize;
        while let Ok((lat, ok)) = rx.recv() {
            latencies.push(lat);
            served += 1;
            if ok {
                correct += 1;
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        let elapsed = started.elapsed().as_secs_f64();
        table.row(vec![
            scheme.to_string(),
            format!("{:.2}", latencies.median()),
            format!("{:.2}", latencies.percentile(95.0)),
            format!("{:.2}", latencies.mean()),
            format!("{:.3}", served as f64 / elapsed),
            format!("{:.2}", correct as f64 / served.max(1) as f64),
        ]);
    }
    table.print();

    // --- v2 streaming session: watch a CoT progress step by step ---
    println!("\nstreaming one spec-reason query over the v2 protocol:");
    let mut sc = StreamClient::connect(&addr)?;
    let t0 = Instant::now();
    let id = sc.submit(Json::obj(vec![
        ("dataset", Json::str("math500")),
        ("query_index", Json::num(0.0)),
        ("scheme", Json::str("spec-reason")),
    ]))?;
    loop {
        let (eid, ev) = sc.next_event()?;
        if eid != id {
            continue;
        }
        let at = t0.elapsed().as_secs_f64();
        match ev {
            WireEvent::Queued => println!("  [{at:7.3}s] queued"),
            WireEvent::Admitted => println!("  [{at:7.3}s] admitted"),
            WireEvent::Preempted => println!("  [{at:7.3}s] preempted"),
            WireEvent::Step { kind, step, tokens, score, effective_threshold } => {
                let score = score.map(|s| s.to_string()).unwrap_or_else(|| "-".into());
                let thr = effective_threshold
                    .map(|t| t.to_string())
                    .unwrap_or_else(|| "-".into());
                println!(
                    "  [{at:7.3}s] step {step:>2} {kind:<10} tokens {tokens:>3}  \
                     score {score}/{thr}"
                );
            }
            WireEvent::Result(r) => {
                println!(
                    "  [{at:7.3}s] result: correct={} thinking_tokens={}",
                    r.get("correct").as_bool().unwrap_or(false),
                    r.get("thinking_tokens").as_usize().unwrap_or(0)
                );
                break;
            }
            WireEvent::Error { code, message } => {
                println!("  [{at:7.3}s] error ({code}): {message}");
                break;
            }
            WireEvent::Cancelled => {
                println!("  [{at:7.3}s] cancelled");
                break;
            }
        }
    }

    // --- mid-flight cancel: abort a long request after its first step ---
    println!("cancelling a long query mid-flight:");
    'cancel_demo: {
        let id = sc.submit(Json::obj(vec![
            ("dataset", Json::str("aime")),
            ("query_index", Json::num(1.0)),
            ("budget", Json::num(512.0_f64.min(budget as f64 * 2.0))),
        ]))?;
        loop {
            let (eid, ev) = sc.next_event()?;
            if eid != id {
                continue;
            }
            if matches!(ev, WireEvent::Step { .. }) {
                break;
            }
            if ev.is_terminal() {
                // Rejected at admission (or finished implausibly fast):
                // nothing left to cancel.
                println!("  query ended before the cancel could land: {ev:?}");
                break 'cancel_demo;
            }
        }
        let t0 = Instant::now();
        sc.cancel(id)?;
        loop {
            let (eid, ev) = sc.next_event()?;
            if eid == id && ev.is_terminal() {
                println!(
                    "  cancelled in {:.3}s (terminal: {})",
                    t0.elapsed().as_secs_f64(),
                    match ev {
                        WireEvent::Cancelled => "cancelled".to_string(),
                        other => format!("{other:?}"),
                    }
                );
                break;
            }
        }
    }

    // --- graceful shutdown ---
    let mut client = Client::connect(&addr)?;
    let stats = client.call(Json::obj(vec![("op", Json::str("stats"))]))?;
    println!("router stats: {stats}");
    client.call(Json::obj(vec![("op", Json::str("shutdown"))]))?;
    server_thread.join().unwrap();
    println!("server shut down cleanly");
    Ok(())
}
