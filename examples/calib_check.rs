// throwaway calibration probe
use specreason::coordinator::{Combo, Scheme, SpecConfig, AcceptancePolicy};
use specreason::eval::{run_cell_sim, Cell};
use specreason::semantics::{Dataset, Oracle};

fn main() {
    let oracle = Oracle::default();
    for ds in Dataset::all() {
        for scheme in Scheme::all() {
            let cell = Cell { dataset: ds, scheme, combo: Combo::new("qwq-sim", "r1-sim"),
                cfg: SpecConfig { scheme, policy: AcceptancePolicy::Static { threshold: 7 }, ..Default::default() } };
            let r = run_cell_sim(&oracle, &cell, 40, 4, 1234).unwrap();
            println!("{:8} {:20} acc={:.3} gpu={:7.2}s tok={:6.0} offload={:.2} accept={:.2} draft={:.2}",
                ds.name(), scheme.name(), r.accuracy(), r.mean_gpu(), r.mean_tokens(),
                r.mean_offload(), r.mean_acceptance(),
                r.agg.mean_draft_acceptance());
        }
        println!();
    }
}
