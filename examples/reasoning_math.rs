//! Step-by-step SpecReason walkthrough on a competition-math workload
//! (the Fig. 1 scenario): watch the small model speculate each reasoning
//! step, the base model score it 0–9, and the coordinator accept /
//! reject-and-regenerate — all on real PJRT compute.
//!
//!     make artifacts && cargo run --release --example reasoning_math
//!
//! The transcript shows real decoded bytes (the proxy models' tokens are
//! not semantic — see DESIGN.md §3 — so the *text* is noise while the
//! *mechanics* are real).

use anyhow::Result;

use specreason::coordinator::{Combo, Role, Backend, RealBackend};
use specreason::coordinator::policy::{AcceptancePolicy, StepContext};
use specreason::engine::{Engine, EngineConfig};
use specreason::metrics::Phase;
use specreason::semantics::{Dataset, Oracle, TraceGenerator};

fn main() -> Result<()> {
    println!("loading engine...");
    let engine = Engine::new(&EngineConfig {
        models: vec!["qwq-sim".into(), "r1-sim".into()],
        ..Default::default()
    })?;
    let oracle = Oracle::default();
    let combo = Combo::new("qwq-sim", "r1-sim");
    let policy = AcceptancePolicy::Static { threshold: 7 };
    let q = TraceGenerator::new(Dataset::Math500, 7).query(1);
    println!(
        "MATH500-profile query #{}: difficulty {:.2}, plan of {} steps\n",
        q.index,
        q.difficulty,
        q.plan_len()
    );

    let mut b = RealBackend::new(&engine, &combo.small, &combo.base);
    b.begin(&q)?;

    let budget = 256usize;
    let n_steps = q.plan_len().min(8); // walk the first few steps verbosely
    let mut accepted = 0;
    for step in 0..n_steps {
        if b.thinking_tokens() + 4 > budget {
            println!("[budget] thinking-token budget exhausted");
            break;
        }
        let remaining = budget - b.thinking_tokens();
        let len = oracle.step_tokens(&q, step, 0, &combo.small).min(remaining);
        let spec = &q.plan[step];
        println!(
            "── step {step} {} (difficulty {:.2}, {} tokens) ──",
            if spec.critical { "[critical]" } else { "[routine]" },
            spec.difficulty,
            len
        );

        // 1. small model speculates
        let before = b.thinking_tokens();
        b.decode(Role::Small, len, Phase::Speculate)?;
        let seq = b.sequence().unwrap();
        let text = engine.tokenizer.decode(&seq.tokens[seq.prompt_len + before..]);
        let preview: String = text.chars().take(48).collect();
        println!("  speculated: {preview:?}…");

        // 2. base model verifies in one prefill-only pass
        let quality = oracle.step_quality(&q, step, 0, &combo.small);
        b.verify_pass(70, Phase::Verify)?;
        let score = oracle.verifier_score(&q, step, 0, quality, &combo.base);
        let ctx = StepContext {
            step_index: step,
            plan_len: q.plan_len(),
            budget_left: remaining as f64 / budget as f64,
        };
        let ok = policy.accepts(score, ctx);
        println!(
            "  base model utility score: {score}/9 (latent quality {quality:.2}) → {}",
            if ok { "ACCEPT" } else { "REJECT" }
        );

        // 3. accept or regenerate
        if ok {
            accepted += 1;
        } else {
            b.rollback(len)?;
            let regen = oracle
                .step_tokens(&q, step, 1, &combo.base)
                .min(budget - b.thinking_tokens());
            b.decode(Role::Base, regen, Phase::Fallback)?;
            println!("  base model regenerated the step ({regen} tokens)");
        }
    }

    let m = b.metrics_mut().clone();
    println!("\n── summary ──");
    println!("steps walked: {n_steps}, accepted from speculator: {accepted}");
    println!("thinking tokens: {}", b.thinking_tokens());
    println!("wall time: {:.2}s   gpu-clock: {:.2}s", m.wall_secs, m.gpu_secs);
    for (phase, secs) in &m.phase_wall {
        println!("  {phase:<16} {secs:.2}s wall");
    }
    b.release()?;
    Ok(())
}
