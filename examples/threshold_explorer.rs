//! Acceptance-policy ablation: the paper ships a static threshold (§4.1)
//! and sketches richer strategies as future work.  This example compares
//! the three policies implemented in `coordinator::policy` across the
//! accuracy/latency plane on all datasets (simulator backend: the policy
//! decision logic is identical on the real engine — parity-tested).
//!
//!     cargo run --release --example threshold_explorer

use anyhow::Result;

use specreason::coordinator::{AcceptancePolicy, Combo, Scheme, SpecConfig};
use specreason::eval::{bench_threads, Cell, Sweep};
use specreason::semantics::{Dataset, Oracle};
use specreason::util::bench::Table;

fn main() -> Result<()> {
    let oracle = Oracle::default();
    let combo = Combo::new("qwq-sim", "r1-sim");
    let n_queries = 48;
    let samples = 4;

    let policies: Vec<(String, AcceptancePolicy)> = vec![
        ("static(3)".into(), AcceptancePolicy::Static { threshold: 3 }),
        ("static(5)".into(), AcceptancePolicy::Static { threshold: 5 }),
        ("static(7)".into(), AcceptancePolicy::Static { threshold: 7 }),
        ("static(9)".into(), AcceptancePolicy::Static { threshold: 9 }),
        ("progressive(9→5)".into(), AcceptancePolicy::Progressive { start: 9, end: 5 }),
        ("progressive(8→6)".into(), AcceptancePolicy::Progressive { start: 8, end: 6 }),
        ("budget-aware(7,<25%)".into(), AcceptancePolicy::BudgetAware { threshold: 7, relax_below: 0.25 }),
    ];

    for ds in Dataset::all() {
        // One parallel sweep per dataset: a cell per policy.
        let mut sweep = Sweep::new(n_queries, samples, 1234);
        for (_, policy) in &policies {
            sweep.cell(Cell {
                dataset: ds,
                scheme: Scheme::SpecReason,
                combo: combo.clone(),
                cfg: SpecConfig {
                    scheme: Scheme::SpecReason,
                    policy: *policy,
                    ..Default::default()
                },
            });
        }
        eprintln!(
            "[sweep] {} policies × {} work items on {} threads",
            sweep.cells().len(),
            sweep.items_per_cell(),
            bench_threads()
        );
        let results = sweep.run_sim(&oracle)?;
        let mut t = Table::new(
            &format!("policy ablation — {} (qwq-sim + r1-sim, GPU clock)", ds.name()),
            &["policy", "pass@1", "latency (s)", "acceptance", "tokens"],
        );
        for ((name, _), r) in policies.iter().zip(&results) {
            t.row(vec![
                name.clone(),
                format!("{:.3}", r.accuracy()),
                format!("{:.1}", r.mean_gpu()),
                format!("{:.2}", r.mean_acceptance()),
                format!("{:.0}", r.mean_tokens()),
            ]);
        }
        t.print();
    }
    println!("reading: progressive protects early (planning) steps like the first-n knob\nbut without a hard switch; budget-aware trades late-step strictness for completion.");
    Ok(())
}
