//! Acceptance-policy ablation: the paper ships a static threshold (§4.1)
//! and sketches richer strategies as future work.  This example compares
//! the three policies implemented in `coordinator::policy` across the
//! accuracy/latency plane on all datasets (simulator backend: the policy
//! decision logic is identical on the real engine — parity-tested).
//!
//!     cargo run --release --example threshold_explorer

use anyhow::Result;

use specreason::coordinator::{
    run_query, AcceptancePolicy, Combo, Scheme, SimBackend, SpecConfig,
};
use specreason::eval::testbed_for;
use specreason::metrics::{Aggregate, GpuClock};
use specreason::semantics::{Dataset, Oracle, TraceGenerator};
use specreason::util::bench::Table;

fn main() -> Result<()> {
    let oracle = Oracle::default();
    let combo = Combo::new("qwq-sim", "r1-sim");
    let clock = GpuClock::new(testbed_for(&combo));
    let n_queries = 48;
    let samples = 4;

    let policies: Vec<(String, AcceptancePolicy)> = vec![
        ("static(3)".into(), AcceptancePolicy::Static { threshold: 3 }),
        ("static(5)".into(), AcceptancePolicy::Static { threshold: 5 }),
        ("static(7)".into(), AcceptancePolicy::Static { threshold: 7 }),
        ("static(9)".into(), AcceptancePolicy::Static { threshold: 9 }),
        ("progressive(9→5)".into(), AcceptancePolicy::Progressive { start: 9, end: 5 }),
        ("progressive(8→6)".into(), AcceptancePolicy::Progressive { start: 8, end: 6 }),
        ("budget-aware(7,<25%)".into(), AcceptancePolicy::BudgetAware { threshold: 7, relax_below: 0.25 }),
    ];

    for ds in Dataset::all() {
        let gen = TraceGenerator::new(ds, 1234);
        let queries = gen.queries(n_queries);
        let mut t = Table::new(
            &format!("policy ablation — {} (qwq-sim + r1-sim, GPU clock)", ds.name()),
            &["policy", "pass@1", "latency (s)", "acceptance", "tokens"],
        );
        for (name, policy) in &policies {
            let cfg = SpecConfig {
                scheme: Scheme::SpecReason,
                policy: *policy,
                ..Default::default()
            };
            let mut agg = Aggregate::default();
            for q in &queries {
                for s in 0..samples {
                    let mut b = SimBackend::new(clock, "small", "base");
                    let out = run_query(&oracle, q, &combo, &cfg, &mut b, s)?;
                    agg.push(out.metrics);
                }
            }
            t.row(vec![
                name.clone(),
                format!("{:.3}", agg.accuracy()),
                format!("{:.1}", agg.mean_gpu()),
                format!("{:.2}", agg.mean_acceptance()),
                format!("{:.0}", agg.mean_thinking_tokens()),
            ]);
        }
        t.print();
    }
    println!("reading: progressive protects early (planning) steps like the first-n knob\nbut without a hard switch; budget-aware trades late-step strictness for completion.");
    Ok(())
}
